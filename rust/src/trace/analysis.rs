//! Trace analysis: the concurrency series behind the paper's Figs. 7-9.
//!
//! - Fig. 7: max/min concurrently active *tasks* per day,
//! - Fig. 8: daily distribution of max concurrently running *cloudlets*
//!   at hourly resolution,
//! - Fig. 9: max concurrently running cloudlets by hour-of-day.
//!
//! "Task" concurrency counts SUBMIT..(FINISH|FAIL|KILL|EVICT) windows;
//! "cloudlet" concurrency counts SCHEDULE..end windows (a task only
//! consumes resources once scheduled), mirroring the paper's distinction
//! between task activity and simulation cloudlets.

use super::event::{TaskEventKind, Trace};

/// Concurrency step function: (+1 at start, -1 at end) sorted sweep;
/// samples the active count at `resolution`-second boundaries.
fn concurrency_samples(starts: &[f64], ends: &[f64], horizon: f64, resolution: f64) -> Vec<u64> {
    let mut deltas: Vec<(f64, i64)> = Vec::with_capacity(starts.len() + ends.len());
    deltas.extend(starts.iter().map(|&t| (t, 1i64)));
    deltas.extend(ends.iter().map(|&t| (t, -1i64)));
    deltas.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(b.1.cmp(&a.1)));

    let n_bins = (horizon / resolution).ceil() as usize;
    let mut out = Vec::with_capacity(n_bins);
    let mut active: i64 = 0;
    let mut di = 0;
    let mut peak_in_bin: i64 = 0;
    for bin in 0..n_bins {
        let bin_end = (bin as f64 + 1.0) * resolution;
        while di < deltas.len() && deltas[di].0 <= bin_end {
            active += deltas[di].1;
            peak_in_bin = peak_in_bin.max(active);
            di += 1;
        }
        out.push(peak_in_bin.max(active).max(0) as u64);
        peak_in_bin = active;
    }
    out
}

/// Extract (start, end) pairs for tasks (SUBMIT -> terminal event).
fn task_windows(trace: &Trace) -> (Vec<f64>, Vec<f64>) {
    windows(trace, TaskEventKind::Submit)
}

/// Extract (start, end) pairs for cloudlets (SCHEDULE -> terminal event).
fn cloudlet_windows(trace: &Trace) -> (Vec<f64>, Vec<f64>) {
    windows(trace, TaskEventKind::Schedule)
}

fn windows(trace: &Trace, start_kind: TaskEventKind) -> (Vec<f64>, Vec<f64>) {
    use std::collections::HashMap;
    let mut open: HashMap<(u64, u32), f64> = HashMap::new();
    let mut starts = Vec::new();
    let mut ends = Vec::new();
    for ev in &trace.tasks {
        let key = (ev.job_id, ev.task_index);
        match ev.kind {
            k if k == start_kind => {
                open.entry(key).or_insert(ev.time);
            }
            TaskEventKind::Finish | TaskEventKind::Fail | TaskEventKind::Kill
            | TaskEventKind::Evict => {
                if let Some(s) = open.remove(&key) {
                    starts.push(s);
                    ends.push(ev.time.max(s));
                }
            }
            _ => {}
        }
    }
    // Still-open windows run to the horizon.
    for (_, s) in open {
        starts.push(s);
        ends.push(trace.horizon);
    }
    (starts, ends)
}

/// Fig. 7 row: per-day (day index, max, min) of concurrently active tasks.
pub fn fig7_daily_task_concurrency(trace: &Trace) -> Vec<(usize, u64, u64)> {
    let (starts, ends) = task_windows(trace);
    let samples = concurrency_samples(&starts, &ends, trace.horizon, 3_600.0); // hourly
    per_day_max_min(&samples, 24)
}

/// Fig. 8 row: per-day (day index, max, min) of concurrently *running*
/// cloudlets at hourly resolution.
pub fn fig8_daily_cloudlet_concurrency(trace: &Trace) -> Vec<(usize, u64, u64)> {
    let (starts, ends) = cloudlet_windows(trace);
    let samples = concurrency_samples(&starts, &ends, trace.horizon, 3_600.0);
    per_day_max_min(&samples, 24)
}

/// Fig. 9 series: for each hour-of-day 0-23, the max concurrently running
/// cloudlets observed in that hour across all days.
pub fn fig9_hour_of_day_peaks(trace: &Trace) -> Vec<u64> {
    let (starts, ends) = cloudlet_windows(trace);
    let samples = concurrency_samples(&starts, &ends, trace.horizon, 3_600.0);
    let mut peaks = vec![0u64; 24];
    for (i, &s) in samples.iter().enumerate() {
        let hour = i % 24;
        peaks[hour] = peaks[hour].max(s);
    }
    peaks
}

fn per_day_max_min(samples: &[u64], per_day: usize) -> Vec<(usize, u64, u64)> {
    samples
        .chunks(per_day)
        .enumerate()
        .map(|(day, chunk)| {
            let mx = chunk.iter().copied().max().unwrap_or(0);
            let mn = chunk.iter().copied().min().unwrap_or(0);
            (day, mx, mn)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::TaskEvent;
    use crate::trace::synth::{SynthConfig, TraceGenerator};

    fn ev(time: f64, job: u64, kind: TaskEventKind) -> TaskEvent {
        TaskEvent {
            time,
            job_id: job,
            task_index: 0,
            machine_id: Some(0),
            kind,
            user: 0,
            priority: 0,
            cpu_req: 0.1,
            ram_req: 0.1,
        }
    }

    #[test]
    fn concurrency_counts_overlap() {
        // Two overlapping tasks in hour 0, one lone task in hour 2.
        let trace = Trace {
            machines: vec![],
            tasks: vec![
                ev(100.0, 1, TaskEventKind::Submit),
                ev(200.0, 2, TaskEventKind::Submit),
                ev(1_000.0, 1, TaskEventKind::Finish),
                ev(1_100.0, 2, TaskEventKind::Finish),
                ev(8_000.0, 3, TaskEventKind::Submit),
                ev(9_000.0, 3, TaskEventKind::Finish),
            ],
            horizon: 86_400.0,
        };
        let daily = fig7_daily_task_concurrency(&trace);
        assert_eq!(daily.len(), 1);
        assert_eq!(daily[0].1, 2); // max concurrency
        assert_eq!(daily[0].2, 0); // min concurrency
    }

    #[test]
    fn fig9_has_24_hours_and_peaks_near_peak_hour() {
        let cfg = SynthConfig {
            machines: 20,
            days: 3.0,
            tasks_per_hour: 600.0,
            diurnal_amplitude: 0.6,
            ..Default::default()
        };
        let trace = TraceGenerator::new(cfg.clone()).generate();
        let peaks = fig9_hour_of_day_peaks(&trace);
        assert_eq!(peaks.len(), 24);
        let peak_hour = peaks.iter().enumerate().max_by_key(|(_, &v)| v).unwrap().0 as f64;
        // within +-5h of the configured peak (durations smear the peak)
        let dist = (peak_hour - cfg.peak_hour).abs().min(24.0 - (peak_hour - cfg.peak_hour).abs());
        assert!(dist <= 5.0, "peak at hour {peak_hour}, expected near {}", cfg.peak_hour);
    }

    #[test]
    fn fig7_and_fig8_cover_all_days() {
        let cfg = SynthConfig { machines: 10, days: 2.0, tasks_per_hour: 120.0, ..Default::default() };
        let trace = TraceGenerator::new(cfg).generate();
        assert_eq!(fig7_daily_task_concurrency(&trace).len(), 2);
        assert_eq!(fig8_daily_cloudlet_concurrency(&trace).len(), 2);
        // Task concurrency >= cloudlet concurrency (submit precedes schedule).
        let f7 = fig7_daily_task_concurrency(&trace);
        let f8 = fig8_daily_cloudlet_concurrency(&trace);
        for (a, b) in f7.iter().zip(&f8) {
            assert!(a.1 >= b.1, "day {}: task max {} < cloudlet max {}", a.0, a.1, b.1);
        }
    }
}
