//! Trace -> engine workload conversion (paper §VII-C.1b and §VII-D):
//! machine events become hosts, task submissions are grouped into
//! synthetic VMs per user ("task submissions were grouped into synthetic
//! VMs by user and machine ID"), and a configurable population of spot
//! instances with fixed durations (the paper used 200k at 20/40 hours) is
//! injected on top.

use crate::cloudlet::Cloudlet;
use crate::engine::{Engine, EngineConfig};
use crate::infra::HostSpec;
use crate::stats::Rng;
use crate::vm::{SpotConfig, Vm, VmSpec};

use super::event::{MachineEventKind, TaskEventKind, Trace};

/// Conversion parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    pub seed: u64,
    /// PEs of a machine with normalized capacity 1.0.
    pub pes_per_unit: u32,
    /// MIPS per PE.
    pub mips_per_pe: f64,
    /// RAM (MB) of a machine with normalized capacity 1.0.
    pub ram_per_unit: f64,
    /// Consecutive tasks of one user grouped into one VM.
    pub group_size: usize,
    /// Number of injected spot instances (paper: 200_000; default scaled).
    pub spot_instances: usize,
    /// Fixed spot workload durations in seconds (paper: 20 h / 40 h).
    pub spot_durations: Vec<f64>,
    /// Spot-instance lifecycle settings for the injected spots (paper
    /// §VII-D: hibernation behavior, EC2-style warning, 6 h timeout).
    pub spot: SpotConfig,
    /// Waiting time for persistent trace VMs.
    pub waiting_time: f64,
    /// Cap on trace VMs created (0 = unlimited) - scale knob.
    pub max_trace_vms: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        // pes_per_unit calibrated so injected spots + diurnal trace peaks
        // oversubscribe the cluster (the paper's 12.6k-machine cell is
        // likewise saturated by 200k spots + trace load; at 1/60 machine
        // scale the per-machine capacity must shrink accordingly).
        WorkloadConfig {
            seed: 42,
            pes_per_unit: 8,
            mips_per_pe: 1000.0,
            ram_per_unit: 32_768.0,
            group_size: 6,
            spot_instances: 2_000,
            spot_durations: vec![20.0 * 3_600.0, 40.0 * 3_600.0],
            spot: SpotConfig::hibernate()
                .with_min_running(300.0)
                .with_warning(120.0)
                .with_hibernation_timeout(6.0 * 3_600.0),
            waiting_time: 1_800.0,
            max_trace_vms: 0,
        }
    }
}

/// Engine knobs of the trace substrate (minute scheduling ticks, ~10 min
/// hibernation re-probes - the source of the paper's ~32-minute average
/// interruption durations). Single source of truth shared by
/// `experiments::trace_sim::run` and the sweep driver's `trace_sim` cells.
pub fn trace_engine_config(sample_interval: f64) -> EngineConfig {
    let mut cfg = EngineConfig::default();
    cfg.sample_interval = sample_interval;
    cfg.scheduling_interval = 60.0;
    cfg.vm_destruction_delay = 1.0;
    cfg.resubmit_cooldown = 600.0;
    cfg.retry_interval = 600.0;
    cfg.max_log_events = 200_000;
    cfg
}

/// What was built (reported alongside the run).
#[derive(Debug, Default, Clone)]
pub struct WorkloadStats {
    pub hosts: usize,
    pub host_removals: usize,
    pub trace_vms: usize,
    pub trace_cloudlets: usize,
    pub spot_vms: usize,
    pub truncated_vms: usize,
}

/// Instantiate hosts + VMs + cloudlets from `trace` into `engine`.
pub fn build(engine: &mut Engine, trace: &Trace, cfg: &WorkloadConfig) -> WorkloadStats {
    let mut stats = WorkloadStats::default();
    let mut rng = Rng::new(cfg.seed);
    let dc = engine.add_datacenter("trace-dc", 1.0);

    // ---- hosts from machine events ------------------------------------
    use std::collections::HashMap;
    let mut host_of_machine: HashMap<u64, usize> = HashMap::new();
    for ev in &trace.machines {
        match ev.kind {
            MachineEventKind::Add => {
                if let Some(&h) = host_of_machine.get(&ev.machine_id) {
                    // Re-add after churn: reactivate via scheduled event.
                    engine.sim.schedule_at(
                        ev.time,
                        crate::core::EntityId::Kernel,
                        crate::core::EntityId::Datacenter(dc),
                        crate::engine::Tag::HostAdd(h),
                    );
                } else {
                    let pes = ((ev.cpu * cfg.pes_per_unit as f64).round() as u32).max(1);
                    let spec = HostSpec::new(
                        pes,
                        cfg.mips_per_pe,
                        (ev.ram * cfg.ram_per_unit).max(1024.0),
                        10_000.0,
                        1_000_000.0,
                    );
                    let h = if ev.time <= 0.0 {
                        engine.add_host(dc, spec)
                    } else {
                        engine.add_host_at(dc, spec, ev.time)
                    };
                    host_of_machine.insert(ev.machine_id, h);
                    stats.hosts += 1;
                }
            }
            MachineEventKind::Remove => {
                if let Some(&h) = host_of_machine.get(&ev.machine_id) {
                    engine.remove_host_at(h, ev.time);
                    stats.host_removals += 1;
                }
            }
            MachineEventKind::Update => {} // capacity updates not modeled
        }
    }

    // ---- trace tasks -> grouped on-demand VMs --------------------------
    // Group consecutive submissions per user into VMs of `group_size`.
    let mut groups: HashMap<u32, Vec<&super::event::TaskEvent>> = HashMap::new();
    let mut order: Vec<u32> = Vec::new();
    for ev in trace.tasks.iter().filter(|t| t.kind == TaskEventKind::Submit) {
        let group = groups.entry(ev.user).or_default();
        if group.is_empty() {
            order.push(ev.user);
        }
        group.push(ev);
    }

    'outer: for user in order {
        let tasks = &groups[&user];
        for chunk in tasks.chunks(cfg.group_size) {
            if cfg.max_trace_vms > 0 && stats.trace_vms >= cfg.max_trace_vms {
                stats.truncated_vms += tasks.len() / cfg.group_size;
                break 'outer;
            }
            let submit_at = chunk.iter().map(|t| t.time).fold(f64::INFINITY, f64::min);
            let total_cpu: f64 = chunk.iter().map(|t| t.cpu_req).sum();
            let total_ram: f64 = chunk.iter().map(|t| t.ram_req).sum();
            let pes = ((total_cpu * cfg.pes_per_unit as f64).ceil() as u32).clamp(1, 10);
            let ram = (total_ram * cfg.ram_per_unit).clamp(512.0, 16_384.0);
            let spec = VmSpec::new(cfg.mips_per_pe, pes)
                .with_ram(ram)
                .with_bw(100.0 * pes as f64)
                .with_storage(10_000.0);
            let vm = engine.submit_vm(
                Vm::on_demand(0, spec)
                    .with_persistent(cfg.waiting_time)
                    .with_delay(submit_at),
            );
            stats.trace_vms += 1;
            for task in chunk {
                // Cloudlet length: until the task's terminal event, scaled
                // to the VM's per-PE capacity.
                let duration = terminal_time(trace, task).max(30.0);
                let length = duration * cfg.mips_per_pe;
                engine.submit_cloudlet(Cloudlet::new(0, length, 1).with_vm(vm));
                stats.trace_cloudlets += 1;
            }
        }
    }

    // ---- injected spot instances (paper §VII-D) -------------------------
    for _ in 0..cfg.spot_instances {
        let dur = cfg.spot_durations[rng.below(cfg.spot_durations.len() as u64) as usize];
        let submit_at = rng.uniform(0.0, (trace.horizon * 0.5).max(1.0));
        let pes = 1 + rng.below(4) as u32;
        let spec = VmSpec::new(cfg.mips_per_pe, pes)
            .with_ram(1024.0 * pes as f64)
            .with_bw(100.0 * pes as f64)
            .with_storage(10_000.0);
        let vm = engine.submit_vm(
            Vm::spot(0, spec, cfg.spot)
                .with_persistent(cfg.waiting_time)
                .with_delay(submit_at),
        );
        // Fixed total work "to ensure completion despite interruptions"
        // (§VII-D): length = duration x one PE's MIPS.
        engine.submit_cloudlet(Cloudlet::new(0, dur * cfg.mips_per_pe, 1).with_vm(vm));
        stats.spot_vms += 1;
    }
    stats
}

/// Time of the task's terminal event minus its schedule time.
fn terminal_time(trace: &Trace, submit: &super::event::TaskEvent) -> f64 {
    let key = (submit.job_id, submit.task_index);
    let mut start = submit.time;
    for ev in &trace.tasks {
        if (ev.job_id, ev.task_index) != key || ev.time < submit.time {
            continue;
        }
        match ev.kind {
            TaskEventKind::Schedule => start = ev.time,
            TaskEventKind::Finish | TaskEventKind::Fail | TaskEventKind::Kill
            | TaskEventKind::Evict => return (ev.time - start).max(0.0),
            _ => {}
        }
    }
    trace.horizon - start
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::FirstFit;
    use crate::engine::EngineConfig;
    use crate::trace::synth::{SynthConfig, TraceGenerator};

    fn small_trace() -> Trace {
        TraceGenerator::new(SynthConfig {
            machines: 12,
            days: 0.05, // ~72 min
            tasks_per_hour: 120.0,
            ..Default::default()
        })
        .generate()
    }

    #[test]
    fn build_creates_hosts_and_vms() {
        let trace = small_trace();
        let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        let cfg = WorkloadConfig { spot_instances: 5, ..Default::default() };
        let stats = build(&mut e, &trace, &cfg);
        assert_eq!(stats.hosts, 12);
        assert!(stats.trace_vms > 0);
        assert!(stats.trace_cloudlets >= stats.trace_vms);
        assert_eq!(stats.spot_vms, 5);
        assert_eq!(e.world.hosts.len(), 12);
    }

    #[test]
    fn max_trace_vms_caps_and_counts() {
        let trace = small_trace();
        let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        let cfg = WorkloadConfig { spot_instances: 0, max_trace_vms: 3, ..Default::default() };
        let stats = build(&mut e, &trace, &cfg);
        assert_eq!(stats.trace_vms, 3);
        assert!(stats.truncated_vms > 0, "cap should report truncation");
    }

    #[test]
    fn trace_run_completes_and_spots_interrupt_or_finish() {
        let trace = small_trace();
        let mut e = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        let cfg = WorkloadConfig {
            spot_instances: 30,
            spot_durations: vec![600.0, 1200.0], // scaled-down 20/40h
            max_trace_vms: 80,
            ..Default::default()
        };
        build(&mut e, &trace, &cfg);
        e.terminate_at(trace.horizon);
        let report = e.run();
        assert!(report.events_processed > 100);
        assert_eq!(report.spot.total_spot, 30);
        // Something happened to the spots: finished, interrupted or active.
        assert!(report.spot.uninterrupted_completions + report.spot.interrupted_vms > 0
            || report.still_active > 0);
    }
}
