//! Trace CSV reader/writer in the Google cluster trace table layout
//! (§VII-C.2a of the paper: the extended CloudSim Plus trace reader).
//!
//! Machine events CSV columns (published schema order):
//! `time,machine_id,event_type,platform_id,cpus,memory`
//! Task events CSV columns (subset):
//! `time,missing_info,job_id,task_index,machine_id,event_type,user,
//!  scheduling_class,priority,cpu_request,memory_request,disk_request,
//!  different_machines_restriction`
//!
//! Times are microseconds in the real trace; `TIME_SCALE` converts to
//! simulation seconds. The reader implements the paper's revisions:
//! missing machine capacities are backfilled by replication from other
//! machines, missing task->machine bindings are resolved from later events
//! of the same (job, task) pair, and malformed rows are counted rather
//! than silently dropped.

use std::collections::HashMap;
use std::path::Path;

use super::event::{MachineEvent, MachineEventKind, TaskEvent, TaskEventKind, Trace};

/// Reader result type. Errors are rendered messages (std-only: the
/// default build carries no external error-handling dependency).
pub type Result<T> = std::result::Result<T, String>;

/// Microseconds -> seconds.
const TIME_SCALE: f64 = 1e-6;

/// Read statistics (observability - the paper excluded ~1.7% of tasks for
/// missing mappings and reports it; so do we).
#[derive(Debug, Default, Clone)]
pub struct ReadStats {
    pub machine_rows: usize,
    pub task_rows: usize,
    pub malformed_rows: usize,
    pub backfilled_capacities: usize,
    pub resolved_bindings: usize,
    pub unresolved_bindings: usize,
}

/// Parse the machine-events table.
pub fn read_machine_events(path: &Path, stats: &mut ReadStats) -> Result<Vec<MachineEvent>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.is_empty() || (lineno == 0 && line.starts_with("time")) {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        if f.len() < 6 {
            stats.malformed_rows += 1;
            continue;
        }
        let kind = match f[2] {
            "0" => MachineEventKind::Add,
            "1" => MachineEventKind::Remove,
            "2" => MachineEventKind::Update,
            _ => {
                stats.malformed_rows += 1;
                continue;
            }
        };
        let (Ok(time), Ok(mid)) = (f[0].parse::<f64>(), f[1].parse::<u64>()) else {
            stats.malformed_rows += 1;
            continue;
        };
        out.push(MachineEvent {
            time: time * TIME_SCALE,
            machine_id: mid,
            kind,
            cpu: f[4].parse().unwrap_or(0.0),
            ram: f[5].parse().unwrap_or(0.0),
        });
        stats.machine_rows += 1;
    }
    // Paper: "missing machine attributes were filled by replication".
    let mean_cpu = mean_nonzero(out.iter().map(|m| m.cpu));
    let mean_ram = mean_nonzero(out.iter().map(|m| m.ram));
    for m in out.iter_mut() {
        if m.cpu == 0.0 {
            m.cpu = mean_cpu;
            stats.backfilled_capacities += 1;
        }
        if m.ram == 0.0 {
            m.ram = mean_ram;
            stats.backfilled_capacities += 1;
        }
    }
    out.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap());
    Ok(out)
}

fn mean_nonzero(values: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0, 0usize);
    for v in values {
        if v > 0.0 {
            sum += v;
            n += 1;
        }
    }
    if n == 0 { 0.5 } else { sum / n as f64 }
}

/// Parse the task-events table with binding resolution.
pub fn read_task_events(path: &Path, stats: &mut ReadStats) -> Result<Vec<TaskEvent>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    let mut out: Vec<TaskEvent> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.is_empty() || (lineno == 0 && line.starts_with("time")) {
            continue;
        }
        let f: Vec<&str> = line.split(',').collect();
        if f.len() < 11 {
            stats.malformed_rows += 1;
            continue;
        }
        let kind = match f[5] {
            "0" => TaskEventKind::Submit,
            "1" => TaskEventKind::Schedule,
            "2" => TaskEventKind::Evict,
            "3" => TaskEventKind::Fail,
            "4" => TaskEventKind::Finish,
            "5" => TaskEventKind::Kill,
            _ => {
                stats.malformed_rows += 1;
                continue;
            }
        };
        let (Ok(time), Ok(job_id), Ok(task_index)) =
            (f[0].parse::<f64>(), f[2].parse::<u64>(), f[3].parse::<u32>())
        else {
            stats.malformed_rows += 1;
            continue;
        };
        out.push(TaskEvent {
            time: time * TIME_SCALE,
            job_id,
            task_index,
            machine_id: f[4].parse().ok(),
            kind,
            user: hash_user(f[6]),
            priority: f[8].parse().unwrap_or(0),
            cpu_req: f[9].parse().unwrap_or(0.0),
            ram_req: f[10].parse().unwrap_or(0.0),
        });
        stats.task_rows += 1;
    }
    out.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap());

    // Paper: "task events missing machine IDs were reconciled by checking
    // subsequent events" - propagate bindings backwards per (job, task)
    // using a hash map for O(1) lookups (§VII-C.2a item ii).
    let mut binding: HashMap<(u64, u32), u64> = HashMap::new();
    for ev in out.iter() {
        if let Some(mid) = ev.machine_id {
            binding.entry((ev.job_id, ev.task_index)).or_insert(mid);
        }
    }
    for ev in out.iter_mut() {
        if ev.machine_id.is_none() {
            match binding.get(&(ev.job_id, ev.task_index)) {
                Some(&mid) => {
                    ev.machine_id = Some(mid);
                    stats.resolved_bindings += 1;
                }
                None => stats.unresolved_bindings += 1,
            }
        }
    }
    Ok(out)
}

/// Read both tables from a directory holding `machine_events.csv` and
/// `task_events.csv`.
pub fn read_trace_dir(dir: &Path) -> Result<(Trace, ReadStats)> {
    let mut stats = ReadStats::default();
    let machines = read_machine_events(&dir.join("machine_events.csv"), &mut stats)?;
    let tasks = read_task_events(&dir.join("task_events.csv"), &mut stats)?;
    let horizon = machines
        .iter()
        .map(|m| m.time)
        .chain(tasks.iter().map(|t| t.time))
        .fold(0.0_f64, f64::max);
    Ok((Trace { machines, tasks, horizon }, stats))
}

/// Write a trace back out in the same CSV layout (round-trip tests + lets
/// users inspect the synthetic workload with standard tooling).
pub fn write_trace_dir(trace: &Trace, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir).map_err(|e| format!("creating {}: {e}", dir.display()))?;
    let mut m = String::from("time,machine_id,event_type,platform_id,cpus,memory\n");
    for ev in &trace.machines {
        let code = match ev.kind {
            MachineEventKind::Add => 0,
            MachineEventKind::Remove => 1,
            MachineEventKind::Update => 2,
        };
        m.push_str(&format!(
            "{:.0},{},{},p0,{},{}\n",
            ev.time / TIME_SCALE,
            ev.machine_id,
            code,
            ev.cpu,
            ev.ram
        ));
    }
    std::fs::write(dir.join("machine_events.csv"), m)
        .map_err(|e| format!("writing machine_events.csv: {e}"))?;

    let mut t = String::from(
        "time,missing_info,job_id,task_index,machine_id,event_type,user,scheduling_class,\
         priority,cpu_request,memory_request,disk_request,different_machines_restriction\n",
    );
    for ev in &trace.tasks {
        let code = match ev.kind {
            TaskEventKind::Submit => 0,
            TaskEventKind::Schedule => 1,
            TaskEventKind::Evict => 2,
            TaskEventKind::Fail => 3,
            TaskEventKind::Finish => 4,
            TaskEventKind::Kill => 5,
        };
        t.push_str(&format!(
            "{:.0},,{},{},{},{},u{},0,{},{},{},0,0\n",
            ev.time / TIME_SCALE,
            ev.job_id,
            ev.task_index,
            ev.machine_id.map(|m| m.to_string()).unwrap_or_default(),
            code,
            ev.user,
            ev.priority,
            ev.cpu_req,
            ev.ram_req,
        ));
    }
    std::fs::write(dir.join("task_events.csv"), t)
        .map_err(|e| format!("writing task_events.csv: {e}"))?;
    Ok(())
}

fn hash_user(s: &str) -> u32 {
    // Users are opaque hashes in the trace; we only need a stable small id.
    let mut h: u32 = 2166136261;
    for b in s.bytes() {
        h ^= b as u32;
        h = h.wrapping_mul(16777619);
    }
    h % 100_000
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth::{SynthConfig, TraceGenerator};

    #[test]
    fn roundtrip_through_csv() {
        let cfg = SynthConfig { machines: 10, days: 0.1, tasks_per_hour: 100.0, ..Default::default() };
        let trace = TraceGenerator::new(cfg).generate();
        let dir = std::env::temp_dir().join(format!("cm_trace_rt_{}", std::process::id()));
        write_trace_dir(&trace, &dir).unwrap();
        let (back, stats) = read_trace_dir(&dir).unwrap();
        assert_eq!(back.machines.len(), trace.machines.len());
        assert_eq!(back.tasks.len(), trace.tasks.len());
        assert_eq!(stats.malformed_rows, 0);
        // Times round-trip at microsecond resolution.
        for (a, b) in trace.tasks.iter().zip(&back.tasks) {
            assert!((a.time - b.time).abs() < 1e-3);
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.job_id, b.job_id);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_rows_are_counted_not_fatal() {
        let dir = std::env::temp_dir().join(format!("cm_trace_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("machine_events.csv"),
            "time,machine_id,event_type,platform_id,cpus,memory\n\
             0,1,0,p0,0.5,0.5\nnot-a-row\n100,2,9,p0,0.5,0.5\n",
        )
        .unwrap();
        std::fs::write(
            dir.join("task_events.csv"),
            "time,missing_info,job_id,task_index,machine_id,event_type,user,scheduling_class,priority,cpu_request,memory_request,disk_request,different_machines_restriction\n\
             0,,5,0,1,0,alice,0,2,0.1,0.1,0,0\nbroken\n",
        )
        .unwrap();
        let (trace, stats) = read_trace_dir(&dir).unwrap();
        assert_eq!(trace.machines.len(), 1);
        assert_eq!(trace.tasks.len(), 1);
        // "not-a-row", the event_type-9 machine row, and "broken".
        assert_eq!(stats.malformed_rows, 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn binding_resolution_from_later_events() {
        let dir = std::env::temp_dir().join(format!("cm_trace_bind_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("machine_events.csv"), "0,7,0,p0,0.5,0.5\n").unwrap();
        // SUBMIT has no machine; SCHEDULE binds to machine 7.
        std::fs::write(
            dir.join("task_events.csv"),
            "0,,5,0,,0,bob,0,2,0.1,0.1,0,0\n1000000,,5,0,7,1,bob,0,2,0.1,0.1,0,0\n",
        )
        .unwrap();
        let (trace, stats) = read_trace_dir(&dir).unwrap();
        assert_eq!(stats.resolved_bindings, 1);
        assert_eq!(trace.tasks[0].machine_id, Some(7));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn capacity_backfill() {
        let dir = std::env::temp_dir().join(format!("cm_trace_fill_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("machine_events.csv"),
            "0,1,0,p0,0.5,0.5\n0,2,0,p0,0,0\n",
        )
        .unwrap();
        std::fs::write(dir.join("task_events.csv"), "").unwrap();
        let (trace, stats) = read_trace_dir(&dir).unwrap();
        assert_eq!(stats.backfilled_capacities, 2);
        assert!(trace.machines.iter().all(|m| m.cpu > 0.0 && m.ram > 0.0));
        std::fs::remove_dir_all(&dir).ok();
    }
}
