//! Google-cluster-trace substrate (paper §VII-C).
//!
//! The real 2011 trace is a multi-GB download unavailable offline, so the
//! substrate has three parts (substitution documented in DESIGN.md §6):
//!
//! - [`event`]: the trace data model (machine events + task events in the
//!   published schema's semantics),
//! - [`synth`]: a Borg-like synthetic generator reproducing the trace's
//!   load *shape* (diurnal arrivals, heavy-tailed durations, Zipf users,
//!   priority tiers, machine churn) at configurable scale,
//! - [`reader`]/CSV round-trip: the extended trace reader of §VII-C.2(a)
//!   (task-machine binding, hash-map lookups, EVICT/FAIL handling,
//!   missing-attribute backfill) operating on the same CSV layout as the
//!   real trace tables, so a downloaded trace drops in unchanged.
//!
//! [`analysis`] computes the paper's Figs. 7-9 series; [`workload`] turns
//! a trace into engine VMs/cloudlets (task->VM grouping by user, §VII-C.1b).

pub mod analysis;
pub mod event;
pub mod reader;
pub mod synth;
pub mod workload;

pub use event::{MachineEvent, MachineEventKind, TaskEvent, TaskEventKind, Trace};
pub use synth::{SynthConfig, TraceGenerator};
