//! Synthetic Borg-like trace generator.
//!
//! Reproduces the *statistical shape* the paper reports for the 2011
//! Google trace (§VII-C.1, Figs. 7-9) at configurable scale:
//!
//! - machines of a few capacity classes, ~1% churn (REMOVE + later re-ADD),
//! - Poisson task arrivals with a diurnal sinusoid + noise rate,
//! - lognormal task durations (heavy tail),
//! - per-user task counts ~ Zipf (a few users dominate),
//! - 30% production / 70% preemptible batch priority mix (Borg),
//! - a fraction of batch tasks EVICT or FAIL mid-run and resubmit.
//!
//! Deterministic per seed; identical seeds yield identical traces.

use crate::stats::{Dist, Rng};

use super::event::{MachineEvent, MachineEventKind, TaskEvent, TaskEventKind, Trace};

/// Generator configuration (defaults give a laptop-scale 2-day trace).
#[derive(Debug, Clone, PartialEq)]
pub struct SynthConfig {
    pub seed: u64,
    pub machines: usize,
    pub days: f64,
    /// Mean task arrivals per hour at the diurnal baseline.
    pub tasks_per_hour: f64,
    /// Diurnal amplitude in [0, 1) (peak = base * (1 + amplitude)).
    pub diurnal_amplitude: f64,
    /// Hour-of-day of the arrival peak.
    pub peak_hour: f64,
    /// Number of distinct users (task counts Zipf-distributed over them).
    pub users: usize,
    /// Fraction of machines that churn (remove + re-add) during the trace.
    pub machine_churn: f64,
    /// Probability a batch task gets EVICTed mid-run (then resubmits once).
    pub evict_prob: f64,
    /// Probability a task FAILs mid-run.
    pub fail_prob: f64,
    /// Median task duration in seconds (lognormal).
    pub median_duration: f64,
    /// Lognormal sigma for durations (tail heaviness).
    pub duration_sigma: f64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            seed: 42,
            machines: 200,
            days: 2.0,
            tasks_per_hour: 2_000.0,
            diurnal_amplitude: 0.35,
            peak_hour: 14.0,
            users: 120,
            machine_churn: 0.05,
            evict_prob: 0.04,
            fail_prob: 0.01,
            median_duration: 900.0, // 15 minutes
            duration_sigma: 1.3,
        }
    }
}

impl SynthConfig {
    /// The paper's Figs. 7-9 analysis scale: a 30-day window.
    pub fn month_scale() -> Self {
        SynthConfig { days: 30.0, ..Default::default() }
    }

    pub fn horizon_secs(&self) -> f64 {
        self.days * 86_400.0
    }
}

/// The generator.
pub struct TraceGenerator {
    cfg: SynthConfig,
}

impl TraceGenerator {
    pub fn new(cfg: SynthConfig) -> Self {
        assert!(cfg.machines > 0 && cfg.days > 0.0 && cfg.tasks_per_hour > 0.0);
        TraceGenerator { cfg }
    }

    /// Arrival rate (tasks/sec) at absolute time `t` - diurnal sinusoid.
    pub fn rate_at(&self, t: f64) -> f64 {
        let base = self.cfg.tasks_per_hour / 3_600.0;
        let hour = (t / 3_600.0) % 24.0;
        let phase = (hour - self.cfg.peak_hour) / 24.0 * std::f64::consts::TAU;
        base * (1.0 + self.cfg.diurnal_amplitude * phase.cos())
    }

    /// Generate the full trace.
    pub fn generate(&self) -> Trace {
        let mut rng = Rng::new(self.cfg.seed);
        let mut machine_rng = rng.fork(1);
        let mut task_rng = rng.fork(2);
        let horizon = self.cfg.horizon_secs();

        // ---- machine events -------------------------------------------
        let mut machines = Vec::new();
        for mid in 0..self.cfg.machines as u64 {
            // Three capacity classes like the trace (0.25 / 0.5 / 1.0).
            let class = [0.25, 0.5, 1.0][machine_rng.below(3) as usize];
            machines.push(MachineEvent {
                time: 0.0,
                machine_id: mid,
                kind: MachineEventKind::Add,
                cpu: class,
                ram: class,
            });
            if machine_rng.chance(self.cfg.machine_churn) {
                // Remove somewhere in the middle, re-add ~2h later.
                let t_rm = machine_rng.uniform(0.2, 0.7) * horizon;
                let t_re = (t_rm + machine_rng.uniform(1_800.0, 14_400.0)).min(horizon * 0.95);
                machines.push(MachineEvent {
                    time: t_rm,
                    machine_id: mid,
                    kind: MachineEventKind::Remove,
                    cpu: class,
                    ram: class,
                });
                machines.push(MachineEvent {
                    time: t_re,
                    machine_id: mid,
                    kind: MachineEventKind::Add,
                    cpu: class,
                    ram: class,
                });
            }
        }
        machines.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap());

        // ---- task events ----------------------------------------------
        let duration_dist = Dist::LogNormal {
            mu: self.cfg.median_duration.ln(),
            sigma: self.cfg.duration_sigma,
        };
        let user_dist = Dist::Zipf { n: self.cfg.users as u64, s: 1.1 };

        let mut tasks = Vec::new();
        let mut t = 0.0;
        let mut job_id: u64 = 1000;
        while t < horizon {
            // Thinning-free approximation: sample interarrival at current
            // rate (rate varies slowly vs interarrival times).
            let rate = self.rate_at(t).max(1e-9);
            t += Dist::Exp { lambda: rate }.sample(&mut task_rng);
            if t >= horizon {
                break;
            }
            job_id += 1;
            let user = user_dist.sample(&mut task_rng) as u32 - 1;
            let production = task_rng.chance(0.3);
            let priority = if production {
                9 + task_rng.below(3) as u8
            } else {
                task_rng.below(9) as u8
            };
            let cpu_req = task_rng.uniform(0.01, 0.25);
            let ram_req = task_rng.uniform(0.01, 0.25);
            let machine = task_rng.below(self.cfg.machines as u64);
            let dur = duration_dist.sample_clamped(&mut task_rng, 30.0, 6.0 * 3_600.0);

            let submit = TaskEvent {
                time: t,
                job_id,
                task_index: 0,
                machine_id: Some(machine),
                kind: TaskEventKind::Submit,
                user,
                priority,
                cpu_req,
                ram_req,
            };
            tasks.push(submit);
            let sched_delay = task_rng.uniform(1.0, 8.0); // paper: 80-90% < 4 s
            tasks.push(TaskEvent {
                time: t + sched_delay,
                kind: TaskEventKind::Schedule,
                ..submit
            });

            let end_kind = if !production && task_rng.chance(self.cfg.evict_prob) {
                TaskEventKind::Evict
            } else if task_rng.chance(self.cfg.fail_prob) {
                TaskEventKind::Fail
            } else {
                TaskEventKind::Finish
            };
            let end_frac = if end_kind == TaskEventKind::Finish {
                1.0
            } else {
                task_rng.uniform(0.1, 0.9)
            };
            let t_end = (t + sched_delay + dur * end_frac).min(horizon);
            tasks.push(TaskEvent { time: t_end, kind: end_kind, ..submit });

            // Evicted tasks resubmit once (the trace reader's EVICT
            // handling path).
            if end_kind == TaskEventKind::Evict {
                let t_re = t_end + task_rng.uniform(5.0, 60.0);
                if t_re < horizon {
                    tasks.push(TaskEvent {
                        time: t_re,
                        task_index: 1,
                        kind: TaskEventKind::Submit,
                        ..submit
                    });
                    let t_fin = (t_re + dur * (1.0 - end_frac)).min(horizon);
                    tasks.push(TaskEvent {
                        time: t_fin,
                        task_index: 1,
                        kind: TaskEventKind::Finish,
                        ..submit
                    });
                }
            }
        }
        tasks.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap());

        Trace { machines, tasks, horizon }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SynthConfig {
        SynthConfig { machines: 20, days: 0.5, tasks_per_hour: 200.0, ..Default::default() }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TraceGenerator::new(small()).generate();
        let b = TraceGenerator::new(small()).generate();
        assert_eq!(a.tasks.len(), b.tasks.len());
        assert_eq!(a.machines.len(), b.machines.len());
        assert_eq!(a.tasks.first().map(|t| t.time), b.tasks.first().map(|t| t.time));
        let c = TraceGenerator::new(SynthConfig { seed: 7, ..small() }).generate();
        assert_ne!(a.tasks.len(), c.tasks.len());
    }

    #[test]
    fn trace_is_valid_and_scaled() {
        let trace = TraceGenerator::new(small()).generate();
        assert!(trace.validate().is_empty(), "{:?}", trace.validate());
        assert_eq!(trace.machine_count(), 20);
        // ~200 tasks/hour * 12 h = ~2400 submissions (within 25%).
        let n = trace.task_count() as f64;
        assert!((1_800.0..3_000.0).contains(&n), "task_count {n}");
    }

    #[test]
    fn diurnal_rate_peaks_at_peak_hour() {
        let g = TraceGenerator::new(SynthConfig::default());
        let peak = g.rate_at(14.0 * 3_600.0);
        let trough = g.rate_at(2.0 * 3_600.0);
        assert!(peak > trough * 1.4, "peak {peak} trough {trough}");
    }

    #[test]
    fn production_share_near_30pct() {
        let trace = TraceGenerator::new(small()).generate();
        let submits: Vec<_> = trace
            .tasks
            .iter()
            .filter(|t| t.kind == TaskEventKind::Submit && t.task_index == 0)
            .collect();
        let prod = submits.iter().filter(|t| t.is_production()).count() as f64;
        let share = prod / submits.len() as f64;
        assert!((0.2..0.4).contains(&share), "production share {share}");
    }

    #[test]
    fn evictions_exist_and_resubmit() {
        let cfg = SynthConfig { evict_prob: 0.3, ..small() };
        let trace = TraceGenerator::new(cfg).generate();
        let evicts = trace.tasks.iter().filter(|t| t.kind == TaskEventKind::Evict).count();
        assert!(evicts > 0);
        let resubmits = trace
            .tasks
            .iter()
            .filter(|t| t.kind == TaskEventKind::Submit && t.task_index == 1)
            .count();
        assert!(resubmits > 0 && resubmits <= evicts);
    }
}
