//! Spot-instance configuration (paper §V-C/D: interruption behavior,
//! minimum running time, hibernation timeout, warning time).

/// What happens when a spot instance is interrupted (paper §V-D:
//  "interruption behavior (termination or hibernation) ... can be
//  configured individually for each spot instance").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InterruptionBehavior {
    /// The instance is destroyed; its cloudlets are canceled.
    Terminate,
    /// The instance is removed from the host with cloudlets paused and is
    /// resubmitted when capacity returns.
    Hibernate,
}

impl InterruptionBehavior {
    /// Stable lowercase name (CLI vocabulary, sweep-axis values and
    /// artifact columns).
    pub fn name(&self) -> &'static str {
        match self {
            InterruptionBehavior::Terminate => "terminate",
            InterruptionBehavior::Hibernate => "hibernate",
        }
    }

    /// Parse one behavior name (`--axis spot.behavior=...` vocabulary).
    pub fn parse(s: &str) -> Result<InterruptionBehavior, String> {
        match s.trim() {
            "terminate" => Ok(InterruptionBehavior::Terminate),
            "hibernate" => Ok(InterruptionBehavior::Hibernate),
            other => Err(format!(
                "unknown interruption behavior '{other}' (expected terminate | hibernate)"
            )),
        }
    }
}

/// Per-spot-instance timing parameters (paper §V-C list):
///
/// - `min_running_time`: spot instances cannot be interrupted due to
///   capacity contention before running this long.
/// - `warning_time`: grace period between the interruption signal and the
///   actual removal (EC2's two-minute warning).
/// - `hibernation_timeout`: maximum duration in hibernation before the
///   instance is terminated.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpotConfig {
    pub behavior: InterruptionBehavior,
    pub min_running_time: f64,
    pub warning_time: f64,
    pub hibernation_timeout: f64,
}

impl Default for SpotConfig {
    /// Paper-inspired defaults: EC2-style 120 s warning, 5-minute minimum
    /// runtime, 1-hour hibernation window, terminate behavior (the AWS
    /// default when hibernation is not requested).
    fn default() -> Self {
        SpotConfig {
            behavior: InterruptionBehavior::Terminate,
            min_running_time: 300.0,
            warning_time: 120.0,
            hibernation_timeout: 3600.0,
        }
    }
}

impl SpotConfig {
    pub fn hibernate() -> Self {
        SpotConfig { behavior: InterruptionBehavior::Hibernate, ..Default::default() }
    }

    pub fn terminate() -> Self {
        SpotConfig { behavior: InterruptionBehavior::Terminate, ..Default::default() }
    }

    pub fn with_behavior(mut self, behavior: InterruptionBehavior) -> Self {
        self.behavior = behavior;
        self
    }

    pub fn with_warning(mut self, secs: f64) -> Self {
        assert!(secs >= 0.0);
        self.warning_time = secs;
        self
    }

    pub fn with_min_running(mut self, secs: f64) -> Self {
        assert!(secs >= 0.0);
        self.min_running_time = secs;
        self
    }

    pub fn with_hibernation_timeout(mut self, secs: f64) -> Self {
        assert!(secs >= 0.0);
        self.hibernation_timeout = secs;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chain() {
        let c = SpotConfig::hibernate()
            .with_warning(30.0)
            .with_min_running(0.0)
            .with_hibernation_timeout(600.0);
        assert_eq!(c.behavior, InterruptionBehavior::Hibernate);
        assert_eq!(c.warning_time, 30.0);
        assert_eq!(c.min_running_time, 0.0);
        assert_eq!(c.hibernation_timeout, 600.0);
    }

    #[test]
    fn default_is_ec2_like() {
        let c = SpotConfig::default();
        assert_eq!(c.behavior, InterruptionBehavior::Terminate);
        assert_eq!(c.warning_time, 120.0);
    }

    #[test]
    fn behavior_names_round_trip() {
        for b in [InterruptionBehavior::Terminate, InterruptionBehavior::Hibernate] {
            assert_eq!(InterruptionBehavior::parse(b.name()).unwrap(), b);
        }
        assert!(InterruptionBehavior::parse("evaporate").is_err());
        let c = SpotConfig::hibernate().with_behavior(InterruptionBehavior::Terminate);
        assert_eq!(c.behavior, InterruptionBehavior::Terminate);
    }
}
