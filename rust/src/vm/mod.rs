//! Virtual machine model: dynamic VMs with spot/on-demand differentiation,
//! lifecycle states and execution histories (paper §V-C/D/E).

pub mod history;
pub mod spot;
pub mod state;
pub mod vm;

pub use history::ExecutionHistory;
pub use spot::{InterruptionBehavior, SpotConfig};
pub use state::VmState;
pub use vm::{Vm, VmSpec, VmType};

/// Index of a VM in the world's VM arena.
pub type VmId = usize;
