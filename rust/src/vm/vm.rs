//! The dynamic VM (paper §V-E(c,d): `DynamicVm` with its two concrete
//! subclasses `OnDemandInstance` and `SpotInstance`).

use super::history::ExecutionHistory;
use super::spot::SpotConfig;
use super::state::VmState;
use crate::cloudlet::CloudletId;
use crate::infra::HostId;

/// Resource request of a VM (paper Table III row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VmSpec {
    /// Requested processing elements.
    pub pes: u32,
    /// Requested MIPS per PE.
    pub mips: f64,
    /// RAM in MB.
    pub ram: f64,
    /// Bandwidth in Mbps.
    pub bw: f64,
    /// Storage in MB.
    pub storage: f64,
}

impl VmSpec {
    pub fn new(mips: f64, pes: u32) -> Self {
        // Mirrors `new SpotInstance(1000, 2, ...)`: mips + pes first,
        // remaining resources via with_* builders (paper Listing 6).
        VmSpec { pes, mips, ram: 512.0, bw: 1000.0, storage: 10_000.0 }
    }

    pub fn with_ram(mut self, ram: f64) -> Self {
        self.ram = ram;
        self
    }

    pub fn with_bw(mut self, bw: f64) -> Self {
        self.bw = bw;
        self
    }

    pub fn with_storage(mut self, storage: f64) -> Self {
        self.storage = storage;
        self
    }

    /// Total requested CPU capacity in MIPS.
    pub fn total_mips(&self) -> f64 {
        self.pes as f64 * self.mips
    }

    /// Request vector in artifact dimension order (CPU, RAM, BW, storage).
    pub fn request_vec(&self) -> [f64; 4] {
        [self.total_mips(), self.ram, self.bw, self.storage]
    }
}

/// Purchase model of an instance (paper §II-B / §V-D: "differentiation of
/// virtual machine types").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VmType {
    OnDemand,
    Spot,
}

impl std::fmt::Display for VmType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            VmType::OnDemand => "On-Demand",
            VmType::Spot => "Spot",
        })
    }
}

/// A dynamic VM instance.
#[derive(Debug, Clone)]
pub struct Vm {
    pub id: super::VmId,
    pub broker: usize,
    pub spec: VmSpec,
    pub vm_type: VmType,
    /// Spot-specific parameters; `None` for on-demand instances.
    pub spot: Option<SpotConfig>,
    /// Persistent requests survive failed allocation and wait (paper §V-D:
    /// "persistent allocation requests").
    pub persistent: bool,
    /// Maximum time a persistent request stays in the waiting queue.
    pub waiting_time: f64,
    /// Broker submission delay (`setSubmissionDelay`).
    pub submission_delay: f64,
    pub state: VmState,
    pub host: Option<HostId>,
    /// Cloudlets bound to this VM.
    pub cloudlets: Vec<CloudletId>,
    pub history: ExecutionHistory,
    /// Count of interruption events (warn->removal completions).
    pub interruptions: u32,
    pub submitted_at: Option<f64>,
    pub hibernated_at: Option<f64>,
    /// Set when the VM reached a final state.
    pub stopped_at: Option<f64>,
    /// Last time this (on-demand) VM triggered spot preemption; throttles
    /// re-preemption while the freed capacity is still materializing.
    pub preempt_armed_at: Option<f64>,
    /// When the VM was displaced from a host it was running on
    /// (hibernation or eviction-requeue); cleared on re-placement or a
    /// terminal state. Feeds the time-to-recover resilience metrics.
    pub displaced_at: Option<f64>,
    /// Whether a periodic backstop retry event is already scheduled
    /// (dedupes the engine's hibernation retry stream).
    pub retry_armed: bool,
    /// Progress (MI) captured by a recovery checkpoint during the
    /// current warning window; consumed when the interruption fires and
    /// cleared on re-placement (see `crate::recovery`).
    pub checkpoint_mi: Option<f64>,
}

impl Vm {
    pub fn on_demand(id: super::VmId, spec: VmSpec) -> Self {
        Vm {
            id,
            broker: 0,
            spec,
            vm_type: VmType::OnDemand,
            spot: None,
            persistent: false,
            waiting_time: 0.0,
            submission_delay: 0.0,
            state: VmState::Waiting,
            host: None,
            cloudlets: Vec::new(),
            history: ExecutionHistory::new(),
            interruptions: 0,
            submitted_at: None,
            hibernated_at: None,
            stopped_at: None,
            preempt_armed_at: None,
            displaced_at: None,
            retry_armed: false,
            checkpoint_mi: None,
        }
    }

    pub fn spot(id: super::VmId, spec: VmSpec, config: SpotConfig) -> Self {
        let mut vm = Vm::on_demand(id, spec);
        vm.vm_type = VmType::Spot;
        vm.spot = Some(config);
        vm
    }

    pub fn with_persistent(mut self, waiting_time: f64) -> Self {
        self.persistent = true;
        self.waiting_time = waiting_time;
        self
    }

    pub fn with_delay(mut self, delay: f64) -> Self {
        assert!(delay >= 0.0);
        self.submission_delay = delay;
        self
    }

    pub fn is_spot(&self) -> bool {
        self.vm_type == VmType::Spot
    }

    /// State transition with legality check (engine invariant).
    ///
    /// Raw struct-level transition: code holding a `World` must go
    /// through `World::transition_vm` instead, which wraps this and also
    /// maintains the O(1) sampling counters and SoA state column.
    pub fn transition(&mut self, next: VmState) {
        assert!(
            self.state.can_transition_to(next),
            "vm {}: illegal transition {:?} -> {:?}",
            self.id,
            self.state,
            next
        );
        self.state = next;
    }

    /// How long the VM has been running in its current interval.
    pub fn current_runtime(&self, now: f64) -> f64 {
        match self.history.intervals().last() {
            Some(iv) if iv.stop.is_none() => (now - iv.start).max(0.0),
            _ => 0.0,
        }
    }

    /// Whether a capacity-driven interruption is currently allowed
    /// (spot + placed + past its minimum running time + not already warned).
    pub fn interruptible(&self, now: f64) -> bool {
        match (&self.spot, self.state) {
            (Some(cfg), VmState::Running) => self.current_runtime(now) + 1e-9 >= cfg.min_running_time,
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::InterruptionBehavior;

    #[test]
    fn spec_builder_mirrors_paper_listing() {
        // new SpotInstance(1000, 2, true); setRam(512); setBw(1000); setSize(10000)
        let spec = VmSpec::new(1000.0, 2).with_ram(512.0).with_bw(1000.0).with_storage(10_000.0);
        assert_eq!(spec.total_mips(), 2000.0);
        assert_eq!(spec.request_vec(), [2000.0, 512.0, 1000.0, 10_000.0]);
    }

    #[test]
    fn spot_construction() {
        let vm = Vm::spot(3, VmSpec::new(1000.0, 2), SpotConfig::hibernate());
        assert!(vm.is_spot());
        assert_eq!(vm.spot.unwrap().behavior, InterruptionBehavior::Hibernate);
        assert_eq!(vm.state, VmState::Waiting);
    }

    #[test]
    fn interruptible_requires_min_runtime() {
        let cfg = SpotConfig::terminate().with_min_running(10.0);
        let mut vm = Vm::spot(0, VmSpec::new(1000.0, 1), cfg);
        vm.transition(VmState::Running);
        vm.history.record_start(0, 100.0);
        assert!(!vm.interruptible(105.0));
        assert!(vm.interruptible(110.0));
    }

    #[test]
    fn on_demand_never_interruptible() {
        let mut vm = Vm::on_demand(0, VmSpec::new(1000.0, 1));
        vm.transition(VmState::Running);
        vm.history.record_start(0, 0.0);
        assert!(!vm.interruptible(1e9));
    }

    #[test]
    #[should_panic(expected = "illegal transition")]
    fn transition_guard() {
        let mut vm = Vm::on_demand(0, VmSpec::new(1000.0, 1));
        vm.transition(VmState::Hibernated); // Waiting -> Hibernated is illegal
    }
}
