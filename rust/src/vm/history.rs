//! Execution history of a VM (paper §V-E(e): `ExecutionHistory` "records
//! execution intervals of spot instances, including host, start, and stop
//! times", enabling average-interruption-time computation).

use crate::infra::HostId;

/// One contiguous period of execution on a host.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interval {
    pub host: HostId,
    pub start: f64,
    /// `None` while the VM is still running this interval.
    pub stop: Option<f64>,
}

/// Append-only record of a VM's execution intervals.
#[derive(Debug, Clone, Default)]
pub struct ExecutionHistory {
    intervals: Vec<Interval>,
}

impl ExecutionHistory {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn intervals(&self) -> &[Interval] {
        &self.intervals
    }

    pub fn is_running(&self) -> bool {
        matches!(self.intervals.last(), Some(iv) if iv.stop.is_none())
    }

    /// Record placement on a host at `t`.
    pub fn record_start(&mut self, host: HostId, t: f64) {
        assert!(!self.is_running(), "record_start while an interval is open");
        if let Some(last) = self.intervals.last() {
            assert!(t + 1e-9 >= last.stop.unwrap(), "intervals must be ordered");
        }
        self.intervals.push(Interval { host, start: t, stop: None });
    }

    /// Record removal from the current host at `t`.
    pub fn record_stop(&mut self, t: f64) {
        let iv = self.intervals.last_mut().expect("record_stop without start");
        assert!(iv.stop.is_none(), "interval already closed");
        assert!(t + 1e-9 >= iv.start, "stop before start");
        iv.stop = Some(t);
    }

    /// Total time spent executing (closed intervals only, plus an open
    /// interval up to `now` if provided).
    pub fn total_runtime(&self, now: Option<f64>) -> f64 {
        self.intervals
            .iter()
            .map(|iv| match (iv.stop, now) {
                (Some(s), _) => s - iv.start,
                (None, Some(n)) => (n - iv.start).max(0.0),
                (None, None) => 0.0,
            })
            .sum()
    }

    /// Gaps between consecutive intervals = interruption durations
    /// (hibernation / waiting periods between execution bursts).
    pub fn interruption_durations(&self) -> Vec<f64> {
        self.intervals
            .windows(2)
            .filter_map(|w| w[0].stop.map(|s| (w[1].start - s).max(0.0)))
            .collect()
    }

    /// The paper's `calculateAverageInterruptionTime` (Fig. 6 column).
    /// `None` when the VM was never resumed after a stop.
    pub fn average_interruption_time(&self) -> Option<f64> {
        let gaps = self.interruption_durations();
        if gaps.is_empty() {
            None
        } else {
            Some(gaps.iter().sum::<f64>() / gaps.len() as f64)
        }
    }

    /// Number of resumptions (= completed interruption->redeploy cycles).
    pub fn resumptions(&self) -> usize {
        self.intervals.len().saturating_sub(1)
    }

    /// First start / last stop (for table output).
    pub fn first_start(&self) -> Option<f64> {
        self.intervals.first().map(|iv| iv.start)
    }

    pub fn last_stop(&self) -> Option<f64> {
        self.intervals.last().and_then(|iv| iv.stop)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_ordered_intervals() {
        let mut h = ExecutionHistory::new();
        h.record_start(1, 10.0);
        h.record_stop(32.0);
        h.record_start(2, 54.0);
        h.record_stop(60.0);
        assert_eq!(h.intervals().len(), 2);
        assert_eq!(h.total_runtime(None), 28.0);
        assert_eq!(h.interruption_durations(), vec![22.0]);
        assert_eq!(h.average_interruption_time(), Some(22.0));
        assert_eq!(h.resumptions(), 1);
        assert_eq!(h.first_start(), Some(10.0));
        assert_eq!(h.last_stop(), Some(60.0));
    }

    #[test]
    fn open_interval_runtime_uses_now() {
        let mut h = ExecutionHistory::new();
        h.record_start(0, 5.0);
        assert!(h.is_running());
        assert_eq!(h.total_runtime(Some(9.0)), 4.0);
        assert_eq!(h.total_runtime(None), 0.0);
        assert_eq!(h.average_interruption_time(), None);
    }

    #[test]
    fn multiple_gaps_average() {
        let mut h = ExecutionHistory::new();
        h.record_start(0, 0.0);
        h.record_stop(10.0);
        h.record_start(0, 20.0); // gap 10
        h.record_stop(30.0);
        h.record_start(1, 60.0); // gap 30
        h.record_stop(70.0);
        assert_eq!(h.average_interruption_time(), Some(20.0));
        assert_eq!(h.resumptions(), 2);
    }

    #[test]
    #[should_panic(expected = "interval is open")]
    fn rejects_double_start() {
        let mut h = ExecutionHistory::new();
        h.record_start(0, 0.0);
        h.record_start(1, 1.0);
    }

    #[test]
    #[should_panic(expected = "without start")]
    fn rejects_stop_without_start() {
        let mut h = ExecutionHistory::new();
        h.record_stop(1.0);
    }
}
