//! VM lifecycle states (paper Fig. 4: spot instance lifecycle state
//! transitions; `DynamicVm` "explicit VM states (e.g., WAITING,
//! INTERRUPTED, TERMINATED)", §V-E(c)).

use std::fmt;

/// Lifecycle state of a dynamic VM.
///
/// Transition diagram (paper Fig. 4; engine-enforced, asserted in tests):
///
/// ```text
///  Waiting ──allocate──► Running ──cloudlets done──► Finished
///    │  ▲                  │ │
///    │  └──── resubmit ────┘ │ (hibernate)            (terminate)
///    │                       ├──warn──► InterruptWarned ──► Terminated
///  timeout                   │                    │
///    ▼                       ▼                    ▼ (hibernate)
///  Failed ◄──timeout── Hibernated ◄───────────────┘
///                          │
///                          └──resume──► Running
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VmState {
    /// Submitted but not (or no longer) placed; persistent requests wait
    /// here up to their waiting time.
    Waiting,
    /// Placed on a host and executing cloudlets.
    Running,
    /// Interruption signal received; grace period (warning time) running.
    InterruptWarned,
    /// Removed from its host with cloudlets paused; awaiting resubmission.
    Hibernated,
    /// All cloudlets completed and the VM was destroyed normally.
    Finished,
    /// Interrupted with terminate behavior, or hibernation timed out.
    Terminated,
    /// Never placed within its waiting time (request expired / rejected).
    Failed,
}

impl VmState {
    /// Whether the VM currently occupies host resources.
    pub fn on_host(self) -> bool {
        matches!(self, VmState::Running | VmState::InterruptWarned)
    }

    /// Whether this is a terminal state.
    pub fn is_final(self) -> bool {
        matches!(self, VmState::Finished | VmState::Terminated | VmState::Failed)
    }

    /// Legal state transitions (engine invariant).
    pub fn can_transition_to(self, next: VmState) -> bool {
        use VmState::*;
        matches!(
            (self, next),
            (Waiting, Running)
                | (Waiting, Failed)
                | (Running, Finished)
                | (Running, InterruptWarned)
                | (Running, Hibernated)   // zero warning time shortcut
                | (Running, Terminated)   // zero warning time shortcut / host removal
                | (Running, Waiting)      // host removed: on-demand requeue
                | (InterruptWarned, Hibernated)
                | (InterruptWarned, Terminated)
                | (InterruptWarned, Finished) // finished during grace period
                | (Hibernated, Running)
                | (Hibernated, Terminated)
        )
    }
}

impl fmt::Display for VmState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            VmState::Waiting => "WAITING",
            VmState::Running => "RUNNING",
            VmState::InterruptWarned => "INTERRUPT_WARNED",
            VmState::Hibernated => "HIBERNATED",
            VmState::Finished => "FINISHED",
            VmState::Terminated => "TERMINATED",
            VmState::Failed => "FAILED",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::VmState::*;

    #[test]
    fn lifecycle_happy_path() {
        assert!(Waiting.can_transition_to(Running));
        assert!(Running.can_transition_to(Finished));
        assert!(Finished.is_final());
    }

    #[test]
    fn interruption_paths() {
        assert!(Running.can_transition_to(InterruptWarned));
        assert!(InterruptWarned.can_transition_to(Hibernated));
        assert!(InterruptWarned.can_transition_to(Terminated));
        assert!(Hibernated.can_transition_to(Running));
        assert!(Hibernated.can_transition_to(Terminated));
    }

    #[test]
    fn illegal_transitions_rejected() {
        assert!(!Finished.can_transition_to(Running));
        assert!(!Failed.can_transition_to(Waiting));
        assert!(!Terminated.can_transition_to(Running));
        assert!(!Waiting.can_transition_to(Hibernated));
    }

    #[test]
    fn on_host_only_when_placed() {
        assert!(Running.on_host());
        assert!(InterruptWarned.on_host());
        assert!(!Hibernated.on_host());
        assert!(!Waiting.on_host());
    }
}
