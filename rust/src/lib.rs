//! # cloudmarket
//!
//! A Rust + JAX + Pallas reproduction of *"Simulating Dynamic Cloud
//! Marketspaces: Modeling Spot Instance Behavior and Scheduling with
//! CloudSim Plus"* (Goldgruber, Pittl, Schikuta; CS.DC 2025).
//!
//! The crate re-implements the paper's entire system as a three-layer
//! stack (see DESIGN.md):
//!
//! - **L3 (this crate)**: a CloudSim-Plus-class discrete-event cloud
//!   simulator with first-class spot-instance lifecycle support
//!   (interruption, hibernation, resubmission), the HLEM-VMP allocation
//!   algorithm and its spot-load-adjusted variant, baseline heuristics,
//!   a Google-cluster-trace substrate, metrics/reporting, and the
//!   spot-advisor correlation analysis.
//! - **L2/L1 (python/, build-time only)**: the HLEM-VMP scoring pipeline
//!   and the batched cloudlet-progress update as JAX functions over pallas
//!   kernels, AOT-lowered to HLO text.
//! - **Runtime**: `runtime` (behind the off-by-default `pjrt` cargo
//!   feature) loads the HLO artifacts through PJRT (the `xla` crate) and
//!   serves them to the L3 hot path; [`allocation::scorer`] provides the
//!   bit-faithful pure-rust fallback. The default build is std-only so
//!   the simulator builds offline without the PJRT toolchain.
//!
//! Quickstart: see `examples/quickstart.rs` or run
//! `cargo run --release -- quickstart`.

pub mod allocation;
pub mod analysis;
pub mod benchkit;
pub mod chaos;
pub mod cloudlet;
pub mod config;
pub mod core;
pub mod engine;
pub mod experiments;
pub mod infra;
pub mod market;
pub mod metrics;
pub mod obs;
pub mod recovery;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod testkit;
pub mod stats;
pub mod sweep;
pub mod trace;
pub mod util;
pub mod vm;
