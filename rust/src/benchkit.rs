//! Minimal benchmark harness (criterion is unavailable offline, DESIGN.md
//! §7). Used by every file in `benches/` via `harness = false`.
//!
//! Methodology: warmup iterations, then timed iterations until both a
//! minimum iteration count and a minimum measuring time are reached;
//! reports median / mean / p95 / min over per-iteration wall times and
//! derived throughput. Deterministic workloads (seeded PRNGs) keep runs
//! comparable across code changes.

use std::time::{Duration, Instant};

use crate::stats::Summary;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iterations: u64,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.median.as_secs_f64())
    }

    pub fn render(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>10.2} Mitems/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>10.2} Kitems/s", t / 1e3),
            Some(t) => format!("  {t:>10.2} items/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} median  {:>12} mean  {:>12} p95  ({} iters){}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.p95),
            self.iterations,
            tp
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Bench configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    pub warmup_iters: u64,
    pub min_iters: u64,
    pub max_iters: u64,
    pub min_time: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            min_time: Duration::from_millis(300),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick preset for expensive end-to-end benches.
    pub fn heavy() -> Self {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            min_time: Duration::from_millis(500),
            ..Default::default()
        }
    }

    /// Time `f`, which must re-do the full work each call. Returns and
    /// records the result. `items` is the per-iteration workload size for
    /// throughput.
    pub fn bench<F: FnMut()>(&mut self, name: &str, items: Option<f64>, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut times = Summary::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while (iters < self.min_iters || start.elapsed() < self.min_time)
            && iters < self.max_iters
        {
            let t0 = Instant::now();
            f();
            times.add(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        let result = BenchResult {
            name: name.to_string(),
            iterations: iters,
            median: Duration::from_secs_f64(times.median()),
            mean: Duration::from_secs_f64(times.mean()),
            p95: Duration::from_secs_f64(times.percentile(95.0)),
            min: Duration::from_secs_f64(times.min()),
            items_per_iter: items,
        };
        println!("{}", result.render());
        self.results.push(result.clone());
        result
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Record an externally measured result (e.g. one phase of a single
    /// instrumented run, where re-running the workload per iteration would
    /// be prohibitive) alongside the timed benches.
    pub fn record(&mut self, result: BenchResult) {
        println!("{}", result.render());
        self.results.push(result);
    }

    /// Append another bencher's recorded results (lets differently-tuned
    /// benchers - e.g. a `heavy()` end-to-end pass - share one JSON
    /// trajectory file).
    pub fn merge(&mut self, other: &Bencher) {
        self.results.extend(other.results.iter().cloned());
    }

    /// Write all results as a JSON array (consumed by EXPERIMENTS.md
    /// tooling / CI trend lines).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        use crate::util::json::{Json, JsonObj};
        let arr: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut o = JsonObj::new();
                o.set("name", Json::Str(r.name.clone()));
                o.set("iterations", Json::Num(r.iterations as f64));
                o.set("median_ns", Json::Num(r.median.as_nanos() as f64));
                o.set("mean_ns", Json::Num(r.mean.as_nanos() as f64));
                o.set("p95_ns", Json::Num(r.p95.as_nanos() as f64));
                if let Some(t) = r.throughput() {
                    o.set("throughput_per_s", Json::Num(t));
                }
                Json::Obj(o)
            })
            .collect();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, Json::Arr(arr).to_string_pretty())
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// `BENCH_FAST=1` (or any non-empty value other than `0`/`false`): benches
/// should skip their most expensive tiers (CI smoke mode). One definition
/// so every bench accepts the same value set.
pub fn fast_mode() -> bool {
    matches!(
        std::env::var("BENCH_FAST").ok().as_deref(),
        Some(v) if !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false")
    )
}

/// Standard bench banner.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_records() {
        let mut b = Bencher {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 50,
            min_time: Duration::from_millis(1),
            results: Vec::new(),
        };
        let r = b.bench("spin", Some(1000.0), || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            black_box(acc);
        });
        assert!(r.iterations >= 5);
        assert!(r.median.as_nanos() > 0);
        assert!(r.throughput().unwrap() > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn json_export() {
        let mut b = Bencher {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 3,
            min_time: Duration::ZERO,
            results: Vec::new(),
        };
        b.bench("x", None, || {});
        let path = std::env::temp_dir().join(format!("cm_bench_{}.json", std::process::id()));
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::util::json::parse(&text).is_ok());
        std::fs::remove_file(&path).ok();
    }
}
