//! Cloudlets (application tasks) and their execution model (paper §V-B(f)).

pub mod cloudlet;
pub mod utilization;

pub use cloudlet::{allocate_mips, allocate_mips_into, Cloudlet, CloudletState, SchedulerKind};
pub use utilization::UtilizationModel;

/// Index of a cloudlet in the world's cloudlet arena.
pub type CloudletId = usize;
