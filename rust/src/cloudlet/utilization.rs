//! Cloudlet resource-utilization models (paper §V-B(f): "resource usage
//! models allow workloads to consume CPU ... in different ways";
//! `UtilizationModelFull` appears in Listing 8).

/// Fraction of the VM's allocated MIPS a cloudlet actually uses at time t.
#[derive(Debug, Clone, PartialEq)]
pub enum UtilizationModel {
    /// Always 100% (the paper's `UtilizationModelFull`).
    Full,
    /// A constant fraction in [0, 1].
    Constant(f64),
    /// Linear ramp from `from` to `to` over `duration` seconds, then flat.
    Ramp { from: f64, to: f64, duration: f64 },
    /// Deterministic pseudo-random walk in [lo, hi] (hash of floor(t)):
    /// stand-in for `UtilizationModelStochastic` without carrying rng state.
    Stochastic { lo: f64, hi: f64, seed: u64 },
}

impl UtilizationModel {
    /// Utilization fraction at absolute simulation time `t` (t >= 0).
    pub fn at(&self, t: f64) -> f64 {
        match *self {
            UtilizationModel::Full => 1.0,
            UtilizationModel::Constant(f) => f.clamp(0.0, 1.0),
            UtilizationModel::Ramp { from, to, duration } => {
                if duration <= 0.0 {
                    return to.clamp(0.0, 1.0);
                }
                let x = (t / duration).clamp(0.0, 1.0);
                (from + (to - from) * x).clamp(0.0, 1.0)
            }
            UtilizationModel::Stochastic { lo, hi, seed } => {
                let step = t.max(0.0).floor() as u64;
                let mut z = step.wrapping_add(seed).wrapping_mul(0x9e3779b97f4a7c15);
                z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
                z ^= z >> 31;
                let u = (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
                (lo + (hi - lo) * u).clamp(0.0, 1.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_and_constant() {
        assert_eq!(UtilizationModel::Full.at(123.0), 1.0);
        assert_eq!(UtilizationModel::Constant(0.25).at(0.0), 0.25);
        assert_eq!(UtilizationModel::Constant(7.0).at(0.0), 1.0); // clamped
    }

    #[test]
    fn ramp_interpolates_and_saturates() {
        let m = UtilizationModel::Ramp { from: 0.2, to: 1.0, duration: 10.0 };
        assert!((m.at(0.0) - 0.2).abs() < 1e-12);
        assert!((m.at(5.0) - 0.6).abs() < 1e-12);
        assert_eq!(m.at(100.0), 1.0);
    }

    #[test]
    fn stochastic_is_deterministic_and_bounded() {
        let m = UtilizationModel::Stochastic { lo: 0.3, hi: 0.9, seed: 42 };
        for t in 0..100 {
            let u = m.at(t as f64);
            assert!((0.3..=0.9).contains(&u), "u={u}");
            assert_eq!(u, m.at(t as f64)); // same t -> same value
        }
        // not constant across steps
        assert_ne!(m.at(1.0), m.at(2.0));
    }
}
