//! Cloudlet: an application task bound to a VM (paper §V-B(f)), plus the
//! VM-level scheduling discipline that divides VM capacity among cloudlets.

use super::utilization::UtilizationModel;
use crate::vm::VmId;

/// Execution state of a cloudlet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CloudletState {
    /// Submitted, waiting for its VM to be placed (or for a PE slot under
    /// space-shared scheduling).
    Queued,
    /// Actively consuming MIPS.
    Running,
    /// VM hibernated: progress frozen, remaining length retained.
    Paused,
    /// Completed all instructions.
    Finished,
    /// VM terminated before completion.
    Canceled,
}

impl std::fmt::Display for CloudletState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            CloudletState::Queued => "QUEUED",
            CloudletState::Running => "RUNNING",
            CloudletState::Paused => "PAUSED",
            CloudletState::Finished => "FINISHED",
            CloudletState::Canceled => "CANCELED",
        })
    }
}

/// How a VM divides its MIPS among its cloudlets (paper §V-B(e):
/// `CloudletScheduler`). Time-shared splits capacity equally among active
/// cloudlets; space-shared runs them PE-exclusively in submission order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    TimeShared,
    SpaceShared,
}

/// An application task (paper Listing 8: `new CloudletSimple(1, 20000, 2)`,
/// file/output sizes, a utilization model, bound to a VM).
#[derive(Debug, Clone)]
pub struct Cloudlet {
    pub id: super::CloudletId,
    pub vm: VmId,
    /// Total length in million instructions (MI).
    pub length_mi: f64,
    /// PEs the cloudlet uses on its VM.
    pub pes: u32,
    pub file_size: f64,
    pub output_size: f64,
    pub utilization: UtilizationModel,
    pub state: CloudletState,
    /// Outstanding instructions (MI).
    pub remaining_mi: f64,
    pub started_at: Option<f64>,
    pub finished_at: Option<f64>,
}

impl Cloudlet {
    pub fn new(id: super::CloudletId, length_mi: f64, pes: u32) -> Self {
        assert!(length_mi > 0.0 && pes > 0);
        Cloudlet {
            id,
            vm: usize::MAX,
            length_mi,
            pes,
            file_size: 300.0,
            output_size: 300.0,
            utilization: UtilizationModel::Full,
            state: CloudletState::Queued,
            remaining_mi: length_mi,
            started_at: None,
            finished_at: None,
        }
    }

    pub fn with_vm(mut self, vm: VmId) -> Self {
        self.vm = vm;
        self
    }

    pub fn with_utilization(mut self, u: UtilizationModel) -> Self {
        self.utilization = u;
        self
    }

    pub fn with_sizes(mut self, file_size: f64, output_size: f64) -> Self {
        self.file_size = file_size;
        self.output_size = output_size;
        self
    }

    pub fn is_active(&self) -> bool {
        matches!(self.state, CloudletState::Running)
    }

    pub fn is_done(&self) -> bool {
        matches!(self.state, CloudletState::Finished | CloudletState::Canceled)
    }

    /// Progress fraction in [0, 1].
    pub fn progress(&self) -> f64 {
        1.0 - (self.remaining_mi / self.length_mi).clamp(0.0, 1.0)
    }
}

/// Compute each cloudlet's allocated MIPS under `kind` for a VM with
/// `vm_mips` total capacity, given the (id, requested_pes) of its active
/// cloudlets. Returns (id, mips) pairs; cloudlets past the PE budget under
/// space-shared get 0 (they queue).
pub fn allocate_mips(
    kind: SchedulerKind,
    vm_mips: f64,
    vm_pes: u32,
    active: &[(super::CloudletId, u32)],
) -> Vec<(super::CloudletId, f64)> {
    let mut out = Vec::new();
    allocate_mips_into(kind, vm_mips, vm_pes, active, &mut out);
    out
}

/// [`allocate_mips`] writing into a reusable buffer (cleared first) - the
/// engine's per-tick MIPS recompute calls this once per running VM, so
/// the allocating variant would pay one heap allocation per VM per tick.
pub fn allocate_mips_into(
    kind: SchedulerKind,
    vm_mips: f64,
    vm_pes: u32,
    active: &[(super::CloudletId, u32)],
    out: &mut Vec<(super::CloudletId, f64)>,
) {
    out.clear();
    if active.is_empty() {
        return;
    }
    match kind {
        SchedulerKind::TimeShared => {
            // Equal split of total VM capacity among all active cloudlets.
            let share = vm_mips / active.len() as f64;
            out.extend(active.iter().map(|&(id, _)| (id, share)));
        }
        SchedulerKind::SpaceShared => {
            // PE-exclusive in submission order; MIPS proportional to PEs.
            let per_pe = if vm_pes == 0 { 0.0 } else { vm_mips / vm_pes as f64 };
            let mut free = vm_pes;
            out.extend(active.iter().map(|&(id, pes)| {
                if free >= pes {
                    free -= pes;
                    (id, per_pe * pes as f64)
                } else {
                    (id, 0.0)
                }
            }));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_progress() {
        let mut c = Cloudlet::new(1, 20_000.0, 2).with_vm(5);
        assert_eq!(c.state, CloudletState::Queued);
        assert_eq!(c.progress(), 0.0);
        c.remaining_mi = 5_000.0;
        assert!((c.progress() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn time_shared_splits_equally() {
        let out = allocate_mips(SchedulerKind::TimeShared, 2000.0, 2, &[(0, 1), (1, 1), (2, 2)]);
        for (_, mips) in &out {
            assert!((mips - 2000.0 / 3.0).abs() < 1e-9);
        }
    }

    #[test]
    fn space_shared_queues_overflow() {
        let out = allocate_mips(SchedulerKind::SpaceShared, 2000.0, 2, &[(0, 1), (1, 1), (2, 1)]);
        assert_eq!(out[0].1, 1000.0);
        assert_eq!(out[1].1, 1000.0);
        assert_eq!(out[2].1, 0.0); // no PE left -> queued
    }

    #[test]
    fn space_shared_multi_pe() {
        let out = allocate_mips(SchedulerKind::SpaceShared, 4000.0, 4, &[(0, 2), (1, 2)]);
        assert_eq!(out[0].1, 2000.0);
        assert_eq!(out[1].1, 2000.0);
    }

    #[test]
    fn empty_active_list() {
        assert!(allocate_mips(SchedulerKind::TimeShared, 1000.0, 1, &[]).is_empty());
    }

    #[test]
    #[should_panic]
    fn rejects_zero_length() {
        Cloudlet::new(0, 0.0, 1);
    }
}
