//! Plain-text table rendering in the style of CloudSim Plus table builders
//! (the paper's Figs. 5-6 show this output format).

use super::csv::Csv;

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A rendered text table; also convertible to [`Csv`].
#[derive(Debug, Clone)]
pub struct TextTable {
    title: String,
    columns: Vec<(String, Align)>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(title: &str) -> Self {
        Self { title: title.to_string(), columns: Vec::new(), rows: Vec::new() }
    }

    pub fn column(mut self, name: &str, align: Align) -> Self {
        self.columns.push((name.to_string(), align));
        self
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.columns.len(), "table row width mismatch");
        self.rows.push(row);
    }

    pub fn row_count(&self) -> usize {
        self.rows.len()
    }

    /// Render the table with a centered title bar, aligned columns and a
    /// separator rule - the CloudSim Plus "SIMULATION RESULTS" style.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.columns.iter().map(|(n, _)| n.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len().saturating_sub(1);

        let mut out = String::new();
        let title = format!(" {} ", self.title);
        let pad = total.saturating_sub(title.chars().count());
        out.push_str(&"=".repeat(pad / 2));
        out.push_str(&title);
        out.push_str(&"=".repeat(pad - pad / 2));
        out.push('\n');

        for (i, ((name, _), w)) in self.columns.iter().zip(&widths).enumerate() {
            if i > 0 {
                out.push_str(" | ");
            }
            out.push_str(&format!("{name:<w$}"));
        }
        out.push('\n');
        out.push_str(&"-".repeat(total));
        out.push('\n');

        for row in &self.rows {
            for (i, (cell, ((_, align), w))) in
                row.iter().zip(self.columns.iter().zip(&widths)).enumerate()
            {
                if i > 0 {
                    out.push_str(" | ");
                }
                match align {
                    Align::Left => out.push_str(&format!("{cell:<w$}")),
                    Align::Right => out.push_str(&format!("{cell:>w$}")),
                }
            }
            out.push('\n');
        }
        out.push_str(&"-".repeat(total));
        out.push('\n');
        out
    }

    /// Export the same data as CSV (paper §V-F: TableBuilderAbstract was
    /// extended with CSV export).
    pub fn to_csv(&self) -> Csv {
        let names: Vec<&str> = self.columns.iter().map(|(n, _)| n.as_str()).collect();
        let mut csv = Csv::new(&names);
        for row in &self.rows {
            csv.push(row.clone());
        }
        csv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TextTable {
        let mut t = TextTable::new("SIMULATION RESULTS")
            .column("ID", Align::Right)
            .column("State", Align::Left);
        t.push(vec!["1".into(), "FINISHED".into()]);
        t.push(vec!["12".into(), "TERMINATED".into()]);
        t
    }

    #[test]
    fn renders_aligned_columns() {
        let r = sample().render();
        assert!(r.contains("SIMULATION RESULTS"));
        assert!(r.contains(" 1 | FINISHED"));
        assert!(r.contains("12 | TERMINATED"));
    }

    #[test]
    fn csv_matches_rows() {
        let csv = sample().to_csv();
        assert_eq!(csv.to_string(), "ID,State\n1,FINISHED\n12,TERMINATED\n");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_bad_rows() {
        let mut t = TextTable::new("t").column("a", Align::Left);
        t.push(vec!["1".into(), "2".into()]);
    }
}
