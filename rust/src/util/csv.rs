//! CSV writer (RFC-4180 quoting) used by the table builders' export path
//! (paper §V-E(f): "support export to CSV/JSON for external analysis").

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// In-memory CSV document with a fixed header row.
#[derive(Debug, Clone)]
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn columns(&self) -> usize {
        self.header.len()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Append a row; panics if the width does not match the header
    /// (catching reporting bugs early is preferable to silent misalignment).
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(
            row.len(),
            self.header.len(),
            "CSV row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_row(&mut out, &self.header);
        for row in &self.rows {
            write_row(&mut out, row);
        }
        out
    }

    pub fn write_file(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        w.write_all(self.to_string().as_bytes())
    }
}

fn write_row(out: &mut String, row: &[String]) {
    for (i, field) in row.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if field.contains([',', '"', '\n', '\r']) {
            out.push('"');
            out.push_str(&field.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(field);
        }
    }
    out.push('\n');
}

/// Format an f64 for CSV/table output: integers without decimals, otherwise
/// two decimal places (matching the paper's table style).
pub fn fmt_num(v: f64) -> String {
    if !v.is_finite() {
        return String::from("");
    }
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut c = Csv::new(&["a", "b"]);
        c.push(vec!["1".into(), "x".into()]);
        c.push(vec!["2".into(), "y".into()]);
        assert_eq!(c.to_string(), "a,b\n1,x\n2,y\n");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn quotes_special_fields() {
        let mut c = Csv::new(&["a"]);
        c.push(vec!["has,comma".into()]);
        c.push(vec!["has\"quote".into()]);
        c.push(vec!["has\nnewline".into()]);
        assert_eq!(c.to_string(), "a\n\"has,comma\"\n\"has\"\"quote\"\n\"has\nnewline\"\n");
    }

    #[test]
    #[should_panic(expected = "CSV row width")]
    fn rejects_misaligned_rows() {
        let mut c = Csv::new(&["a", "b"]);
        c.push(vec!["only-one".into()]);
    }

    #[test]
    fn num_formatting() {
        assert_eq!(fmt_num(3.0), "3");
        assert_eq!(fmt_num(3.14159), "3.14");
        assert_eq!(fmt_num(-0.5), "-0.50");
        assert_eq!(fmt_num(f64::NAN), "");
    }
}
