//! Small self-contained utilities (substrates forced by the offline crate
//! set, see DESIGN.md §7): JSON writer/parser, CSV writer, text tables and
//! a tiny CLI flag parser.

pub mod cli;
pub mod csv;
pub mod json;
pub mod table;
