//! Tiny CLI flag parser (`clap` is unavailable offline, DESIGN.md §7).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Unknown flags are an error so typos fail loudly. Flags may repeat:
//! [`Args::get`] returns the last occurrence (usual CLI override
//! semantics) and [`Args::get_all`] returns every occurrence in order
//! (for accumulating flags like `--axis`).

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
    known: Vec<String>,
}

/// A flag specification: name and whether it takes a value.
#[derive(Debug, Clone)]
pub struct Spec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

impl Args {
    /// Parse `argv` (without the program name) against `specs`.
    pub fn parse(argv: &[String], specs: &[Spec]) -> Result<Args, String> {
        let mut out = Args::default();
        out.known = specs.iter().map(|s| s.name.to_string()).collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}"))?;
                let value = if spec.takes_value {
                    match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    }
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    String::from("true")
                };
                out.flags.entry(name).or_default().push(value);
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn has(&self, name: &str) -> bool {
        debug_assert!(self.known.iter().any(|k| k == name), "flag --{name} not declared");
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        debug_assert!(self.known.iter().any(|k| k == name), "flag --{name} not declared");
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable flag, in command-line order
    /// (empty when absent).
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        debug_assert!(self.known.iter().any(|k| k == name), "flag --{name} not declared");
        self.flags
            .get(name)
            .map(|v| v.iter().map(|s| s.as_str()).collect())
            .unwrap_or_default()
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Like [`Args::get_usize`] but rejects `0` (counts like `--threads`
    /// and `--seeds` are meaningless at zero; fail loudly instead of
    /// silently running nothing).
    pub fn get_positive_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        let v = self.get_usize(name, default)?;
        if v == 0 {
            return Err(format!("--{name} must be >= 1"));
        }
        Ok(v)
    }
}

/// Render a help block for `specs`.
pub fn render_help(specs: &[Spec]) -> String {
    let mut out = String::new();
    for s in specs {
        let arg = if s.takes_value { format!("--{} <v>", s.name) } else { format!("--{}", s.name) };
        out.push_str(&format!("  {arg:<24} {}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<Spec> {
        vec![
            Spec { name: "seed", takes_value: true, help: "rng seed" },
            Spec { name: "verbose", takes_value: false, help: "chatty" },
            Spec { name: "threads", takes_value: true, help: "worker threads" },
            Spec { name: "seeds", takes_value: true, help: "seed count" },
        ]
    }

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_value_flags_both_styles() {
        let a = Args::parse(&argv(&["--seed", "7"]), &specs()).unwrap();
        assert_eq!(a.get("seed"), Some("7"));
        let a = Args::parse(&argv(&["--seed=9"]), &specs()).unwrap();
        assert_eq!(a.get_u64("seed", 0).unwrap(), 9);
    }

    #[test]
    fn parses_bool_and_positional() {
        let a = Args::parse(&argv(&["run", "--verbose", "x"]), &specs()).unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["run", "x"]);
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(Args::parse(&argv(&["--nope"]), &specs()).is_err());
        assert!(Args::parse(&argv(&["--seed"]), &specs()).is_err());
        assert!(Args::parse(&argv(&["--verbose=1"]), &specs()).is_err());
    }

    #[test]
    fn defaults_and_type_errors() {
        let a = Args::parse(&argv(&[]), &specs()).unwrap();
        assert_eq!(a.get_f64("seed", 1.5).unwrap(), 1.5);
        let a = Args::parse(&argv(&["--seed", "abc"]), &specs()).unwrap();
        assert!(a.get_u64("seed", 0).is_err());
    }

    /// Repeated flags accumulate: `get` takes the last, `get_all` keeps
    /// every occurrence in order (`--axis` semantics).
    #[test]
    fn repeated_flags_accumulate() {
        let a = Args::parse(&argv(&["--seed", "1", "--seed", "2", "--seed=3"]), &specs()).unwrap();
        assert_eq!(a.get("seed"), Some("3"), "get() returns the last occurrence");
        assert_eq!(a.get_all("seed"), vec!["1", "2", "3"]);
        let a = Args::parse(&argv(&[]), &specs()).unwrap();
        assert!(a.get_all("seed").is_empty());
    }

    /// `--threads` / `--seeds` sweep flags: positive integers only.
    #[test]
    fn positive_counts_reject_zero_and_non_numeric() {
        let a = Args::parse(&argv(&["--threads", "4", "--seeds", "8"]), &specs()).unwrap();
        assert_eq!(a.get_positive_usize("threads", 1).unwrap(), 4);
        assert_eq!(a.get_positive_usize("seeds", 1).unwrap(), 8);

        let a = Args::parse(&argv(&["--threads", "0"]), &specs()).unwrap();
        let err = a.get_positive_usize("threads", 1).unwrap_err();
        assert!(err.contains("must be >= 1"), "{err}");

        let a = Args::parse(&argv(&["--seeds", "0"]), &specs()).unwrap();
        assert!(a.get_positive_usize("seeds", 1).is_err());

        let a = Args::parse(&argv(&["--threads", "four"]), &specs()).unwrap();
        let err = a.get_positive_usize("threads", 1).unwrap_err();
        assert!(err.contains("expects an integer"), "{err}");

        let a = Args::parse(&argv(&["--threads", "-2"]), &specs()).unwrap();
        assert!(a.get_positive_usize("threads", 1).is_err());

        // Absent flag falls back to the (validated) default.
        let a = Args::parse(&argv(&[]), &specs()).unwrap();
        assert_eq!(a.get_positive_usize("threads", 3).unwrap(), 3);
        assert!(a.get_positive_usize("threads", 0).is_err());
    }
}
