//! Minimal JSON value model, writer and parser.
//!
//! `serde`/`serde_json` do not resolve in the offline crate set (DESIGN.md
//! §7), and the simulator only needs JSON for (a) exporting simulation
//! results (§V-E(f) of the paper: CSV/JSON export of VM lifecycle data) and
//! (b) reading the artifact MANIFEST and optional scenario / advisor files.
//! This module implements a small, strict-enough JSON subset: all of RFC
//! 8259 except `\u` surrogate pairs are supported on input; output is
//! deterministic (insertion-ordered objects).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
///
/// Objects preserve insertion order via a parallel key vector so exported
/// reports are stable and diffable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObj),
}

/// Insertion-ordered JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (or overwrite) a key. Insertion order of first occurrence is
    /// preserved on output.
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        if self.map.insert(key.to_string(), value).is_none() {
            self.keys.push(key.to_string());
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.keys.iter().map(move |k| (k.as_str(), &self.map[k]))
    }
}

impl Json {
    pub fn obj() -> JsonObj {
        JsonObj::new()
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Navigate `obj.key` paths; returns `None` on any miss.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.as_obj()?.get(k)?;
        }
        Some(cur)
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; clamp to null like most tolerant writers.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error message with byte offset on
/// malformed input.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, String> {
        Err(format!("{} at byte {}", msg, self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(&format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected '{word}'"))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{text}' at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return self.err("short \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.set(&key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(obj));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "3e2", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, back, "roundtrip {src}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null}],"c":"x\ny","d":{"e":true}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn object_preserves_insertion_order() {
        let mut o = JsonObj::new();
        o.set("z", Json::Num(1.0));
        o.set("a", Json::Num(2.0));
        o.set("m", Json::Num(3.0));
        let s = Json::Obj(o).to_string_compact();
        assert_eq!(s, r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn path_navigation() {
        let v = parse(r#"{"a":{"b":{"c":42}}}"#).unwrap();
        assert_eq!(v.path(&["a", "b", "c"]).unwrap().as_f64(), Some(42.0));
        assert!(v.path(&["a", "x"]).is_none());
    }

    #[test]
    fn rejects_malformed() {
        for src in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "01x", "[1]extra"] {
            assert!(parse(src).is_err(), "should reject {src}");
        }
    }

    #[test]
    fn escapes_control_chars() {
        let s = Json::Str("a\"b\\c\nd\u{1}".into()).to_string_compact();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\\u0001\"");
        assert_eq!(parse(&s).unwrap(), Json::Str("a\"b\\c\nd\u{1}".into()));
    }

    #[test]
    fn nonfinite_numbers_become_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string_compact(), "null");
    }

    #[test]
    fn parses_manifest_like_doc() {
        let src = r#"{
          "source_hash": "abc",
          "entry_points": {
            "hlem_score": {"file": "hlem_score.hlo.txt", "max_hosts": 128, "dims": 4}
          }
        }"#;
        let v = parse(src).unwrap();
        assert_eq!(
            v.path(&["entry_points", "hlem_score", "max_hosts"]).unwrap().as_f64(),
            Some(128.0)
        );
    }
}
