//! The allocation-policy trait: host selection for plain placement and for
//! placement-with-spot-preemption (the paper's `DynamicAllocation`
//! extension of `VmAllocationPolicyAbstract`).

use crate::engine::world::World;
use crate::infra::HostId;
use crate::vm::VmId;

/// A VM placement strategy.
///
/// Policies receive an immutable world view and must not assume they are
/// called in any particular order; the engine owns all mutation. `&mut
/// self` allows stateful policies (Round-Robin cursor, scorer scratch
/// buffers, decision counters).
///
/// The world view includes the incremental placement index (free-PE
/// buckets, spot-host set, O(1) per-host spot-usage vectors - see
/// [`crate::engine::index`]): policies should query
/// `World::{first,best,worst}_fit_host`, `World::feasible_host_ids` and
/// `World::spot_host_ids` rather than scanning `active_hosts()` per
/// decision. The index is kept consistent by the engine, which routes
/// every commit/release/host-lifecycle change through `World`.
pub trait AllocationPolicy {
    /// Human-readable name used in reports and benches.
    fn name(&self) -> &'static str;

    /// Choose a host with free capacity for `vm`, or `None`.
    fn select_host(&mut self, world: &World, vm: VmId, now: f64) -> Option<HostId>;

    /// Choose a host where interrupting the returned spot VMs would make
    /// room for `vm` (paper §V-C: "the system attempts to free up
    /// resources by interrupting spot instances; the selection of which
    /// host to target ... depends on the active VM allocation policy").
    ///
    /// Only consulted for on-demand VMs after `select_host` failed.
    /// Returns `(host, victims)`; victims must all be interruptible at
    /// `now` and jointly sufficient.
    fn select_preemption(
        &mut self,
        world: &World,
        vm: VmId,
        now: f64,
    ) -> Option<(HostId, Vec<VmId>)>;

    /// Number of placement decisions taken (for perf accounting).
    fn decisions(&self) -> u64 {
        0
    }
}
