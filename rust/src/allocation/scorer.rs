//! Host-scoring backends for HLEM-VMP.
//!
//! [`RustScorer`] is a direct f64 transcription of the oracle in
//! `python/compile/kernels/ref.py` (Eqs. 3-11, same masking / degenerate-
//! case contract - see the module docs there and DESIGN.md §5). The
//! PJRT-backed scorer in [`crate::runtime::PjrtScorer`] executes the AOT
//! artifact built from the L1 pallas kernel; an integration test
//! cross-checks the two to float32 tolerance.

use crate::engine::world::World;
use crate::infra::Host;

/// Number of resource dimensions (CPU, RAM, BW, storage).
pub const DIMS: usize = 4;

/// Score assigned to masked (filtered-out) hosts.
pub const NEG: f64 = -1.0e30;

const EPS: f64 = 1.0e-12;

/// Input to a scoring call: per-host capacity/free/spot-usage vectors plus
/// the candidate mask and the spot-load factor alpha.
pub struct ScoreInput<'a> {
    pub caps: &'a [[f64; DIMS]],
    pub free: &'a [[f64; DIMS]],
    pub spot_used: &'a [[f64; DIMS]],
    pub mask: &'a [bool],
    pub alpha: f64,
}

impl<'a> ScoreInput<'a> {
    pub fn len(&self) -> usize {
        self.caps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.caps.is_empty()
    }

    pub fn validate(&self) {
        assert_eq!(self.caps.len(), self.free.len());
        assert_eq!(self.caps.len(), self.spot_used.len());
        assert_eq!(self.caps.len(), self.mask.len());
    }
}

/// A host-scoring backend: returns (HS, AHS) per host; masked hosts get
/// [`NEG`].
pub trait HostScorer {
    fn name(&self) -> &'static str;
    fn scores(&mut self, input: &ScoreInput) -> (Vec<f64>, Vec<f64>);
}

/// Pure-rust scorer - the production fallback and the semantics oracle on
/// the rust side.
#[derive(Debug, Default)]
pub struct RustScorer;

impl RustScorer {
    pub fn new() -> Self {
        RustScorer
    }

    /// Entropy-derived resource weights w_d (Eqs. 4-8).
    pub fn entropy_weights(free: &[[f64; DIMS]], mask: &[bool]) -> [f64; DIMS] {
        let n_valid = mask.iter().filter(|&&m| m).count() as f64;

        // Eq. (4): proportional shares.
        let mut col_sum = [0.0; DIMS];
        for (row, &m) in free.iter().zip(mask) {
            if m {
                for d in 0..DIMS {
                    col_sum[d] += row[d];
                }
            }
        }
        let uniform = if n_valid > 0.0 { 1.0 / n_valid } else { 0.0 };

        // Eq. (5)-(6): entropy with k = 1/ln(n); k = 0 for n <= 1.
        let k = if n_valid > 1.0 { 1.0 / n_valid.ln() } else { 0.0 };
        let mut e = [0.0; DIMS];
        for d in 0..DIMS {
            let mut acc = 0.0;
            for (row, &m) in free.iter().zip(mask) {
                if !m {
                    continue;
                }
                let p = if col_sum[d] > EPS { row[d] / col_sum[d] } else { uniform };
                if p > 0.0 {
                    acc += p * p.max(EPS).ln();
                }
            }
            e[d] = -k * acc;
        }

        // Eq. (7)-(8): variation factors -> weights.
        let mut g = [0.0; DIMS];
        let mut gsum = 0.0;
        for d in 0..DIMS {
            g[d] = 1.0 - e[d];
            gsum += g[d];
        }
        let mut w = [0.0; DIMS];
        for d in 0..DIMS {
            w[d] = if gsum > EPS { g[d] / gsum } else { 1.0 / DIMS as f64 };
        }
        w
    }
}

impl HostScorer for RustScorer {
    fn name(&self) -> &'static str {
        "rust"
    }

    fn scores(&mut self, input: &ScoreInput) -> (Vec<f64>, Vec<f64>) {
        input.validate();
        let h = input.len();
        let mut hs = vec![NEG; h];
        let mut ahs = vec![NEG; h];
        if h == 0 {
            return (hs, ahs);
        }

        // Eq. (3): min-max bounds over valid hosts per dimension.
        let mut mn = [f64::INFINITY; DIMS];
        let mut mx = [f64::NEG_INFINITY; DIMS];
        for (row, &m) in input.free.iter().zip(input.mask) {
            if m {
                for d in 0..DIMS {
                    mn[d] = mn[d].min(row[d]);
                    mx[d] = mx[d].max(row[d]);
                }
            }
        }

        let w = Self::entropy_weights(input.free, input.mask);

        for i in 0..h {
            if !input.mask[i] {
                continue;
            }
            // Eq. (3) + (9): normalized capacities, weighted sum.
            let mut score = 0.0;
            for d in 0..DIMS {
                let rng = mx[d] - mn[d];
                let c = if rng > EPS { (input.free[i][d] - mn[d]) / rng } else { 0.5 };
                score += w[d] * c;
            }
            // Eq. (10)-(11): spot load and adjusted score.
            let mut sl = 0.0;
            for d in 0..DIMS {
                let frac = if input.caps[i][d] > EPS {
                    input.spot_used[i][d] / input.caps[i][d]
                } else {
                    0.0
                };
                sl += w[d] * frac;
            }
            hs[i] = score;
            ahs[i] = score * (1.0 + input.alpha * sl);
        }
        (hs, ahs)
    }
}

/// Build a `ScoreInput`'s arrays from the world's active hosts with the
/// mask supplied per host id (used by the HLEM policy and by tests).
pub fn collect_host_arrays(
    world: &World,
    hosts: &[&Host],
) -> (Vec<[f64; DIMS]>, Vec<[f64; DIMS]>, Vec<[f64; DIMS]>) {
    let mut caps = Vec::with_capacity(hosts.len());
    let mut free = Vec::with_capacity(hosts.len());
    let mut spot = Vec::with_capacity(hosts.len());
    for h in hosts {
        caps.push(h.capacity_vec());
        free.push(h.free_vec());
        spot.push(world.spot_used_vec(h));
    }
    (caps, free, spot)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    fn rand_input(rng: &mut Rng, h: usize) -> (Vec<[f64; 4]>, Vec<[f64; 4]>, Vec<[f64; 4]>, Vec<bool>) {
        let mut caps = Vec::new();
        let mut free = Vec::new();
        let mut spot = Vec::new();
        let mut mask = Vec::new();
        for _ in 0..h {
            let mut c = [0.0; 4];
            let mut f = [0.0; 4];
            let mut s = [0.0; 4];
            for d in 0..4 {
                c[d] = rng.uniform(1.0, 100.0);
                f[d] = c[d] * rng.next_f64();
                s[d] = f[d] * rng.next_f64();
            }
            caps.push(c);
            free.push(f);
            spot.push(s);
            mask.push(rng.chance(0.8));
        }
        if !mask.iter().any(|&m| m) {
            mask[0] = true;
        }
        (caps, free, spot, mask)
    }

    #[test]
    fn weights_sum_to_one() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let (_, free, _, mask) = rand_input(&mut rng, 16);
            let w = RustScorer::entropy_weights(&free, &mask);
            let sum: f64 = w.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "weights {w:?}");
            assert!(w.iter().all(|&x| x >= -1e-12));
        }
    }

    #[test]
    fn masked_hosts_get_neg() {
        let mut rng = Rng::new(2);
        let (caps, free, spot, mut mask) = rand_input(&mut rng, 8);
        mask[3] = false;
        let (hs, ahs) = RustScorer::new().scores(&ScoreInput {
            caps: &caps,
            free: &free,
            spot_used: &spot,
            mask: &mask,
            alpha: -0.5,
        });
        assert_eq!(hs[3], NEG);
        assert_eq!(ahs[3], NEG);
    }

    #[test]
    fn hs_in_unit_interval_for_valid() {
        let mut rng = Rng::new(3);
        let (caps, free, spot, mask) = rand_input(&mut rng, 32);
        let (hs, _) = RustScorer::new().scores(&ScoreInput {
            caps: &caps,
            free: &free,
            spot_used: &spot,
            mask: &mask,
            alpha: 0.0,
        });
        for (s, &m) in hs.iter().zip(&mask) {
            if m {
                assert!((-1e-9..=1.0 + 1e-9).contains(s), "hs {s}");
            }
        }
    }

    #[test]
    fn alpha_zero_means_identity() {
        let mut rng = Rng::new(4);
        let (caps, free, spot, mask) = rand_input(&mut rng, 16);
        let (hs, ahs) = RustScorer::new().scores(&ScoreInput {
            caps: &caps,
            free: &free,
            spot_used: &spot,
            mask: &mask,
            alpha: 0.0,
        });
        assert_eq!(hs, ahs);
    }

    #[test]
    fn negative_alpha_penalizes_spot_heavy_host() {
        // Two identical hosts, host 1 loaded with spot.
        let caps = vec![[100.0; 4]; 2];
        let free = vec![[40.0; 4]; 2];
        let spot = vec![[0.0; 4], [50.0; 4]];
        let mask = vec![true, true];
        let (_, ahs) = RustScorer::new().scores(&ScoreInput {
            caps: &caps,
            free: &free,
            spot_used: &spot,
            mask: &mask,
            alpha: -0.5,
        });
        assert!(ahs[1] < ahs[0], "ahs {ahs:?}");
    }

    #[test]
    fn single_valid_host_is_finite() {
        let caps = vec![[10.0; 4]; 3];
        let free = vec![[5.0; 4]; 3];
        let spot = vec![[1.0; 4]; 3];
        let mask = vec![false, true, false];
        let (hs, ahs) = RustScorer::new().scores(&ScoreInput {
            caps: &caps,
            free: &free,
            spot_used: &spot,
            mask: &mask,
            alpha: -0.5,
        });
        assert!(hs[1].is_finite() && ahs[1].is_finite());
        assert_eq!(hs[0], NEG);
        assert_eq!(hs[2], NEG);
    }

    #[test]
    fn dominating_host_scores_at_least_as_high() {
        let caps = vec![[100.0; 4]; 3];
        let mut free = vec![[10.0; 4], [20.0; 4], [30.0; 4]];
        free[2] = [35.0, 25.0, 30.0, 40.0]; // dominates host 1
        let spot = vec![[0.0; 4]; 3];
        let mask = vec![true; 3];
        let (hs, _) = RustScorer::new().scores(&ScoreInput {
            caps: &caps,
            free: &free,
            spot_used: &spot,
            mask: &mask,
            alpha: 0.0,
        });
        assert!(hs[2] >= hs[1]);
        assert!(hs[1] >= hs[0]);
    }
}
