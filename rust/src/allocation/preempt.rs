//! Shared spot-victim selection: given a candidate host, pick which spot
//! VMs to interrupt so that `vm` fits (the `spotAllocation` /
//! `terminationBehavior` logic of the paper's `DynamicAllocation` class).

use crate::engine::config::VictimPolicy;
use crate::engine::world::World;
use crate::infra::Host;
use crate::vm::VmId;

/// Reusable buffers for the preemption scan (one per policy instance).
/// The pre-scratch code allocated an `interruptible_spots` Vec per
/// candidate host per decision; these keep the scan allocation-free -
/// the only allocation left is the returned victim set on success.
#[derive(Debug, Default)]
pub struct VictimScratch {
    order: Vec<VmId>,
    chosen: Vec<VmId>,
}

/// Fill `out` with the interruptible spot VMs of `host` ordered according
/// to `policy` (allocation-free twin of [`victim_order`]).
pub fn victim_order_into(
    world: &World,
    host: &Host,
    now: f64,
    policy: VictimPolicy,
    out: &mut Vec<VmId>,
) {
    world.interruptible_spots_into(host, now, out);
    order_victims(world, policy, out);
}

/// Order the interruptible spot VMs of `host` according to `policy`.
///
/// [`VictimPolicy::ListOrder`] is the paper's behavior (host VM-list =
/// allocation order, §IX); the others are the future-work ablations.
pub fn victim_order(world: &World, host: &Host, now: f64, policy: VictimPolicy) -> Vec<VmId> {
    let mut victims = Vec::new();
    victim_order_into(world, host, now, policy, &mut victims);
    victims
}

fn order_victims(world: &World, policy: VictimPolicy, victims: &mut Vec<VmId>) {
    match policy {
        VictimPolicy::ListOrder => {}
        VictimPolicy::Youngest => {
            // Most recently started first (least sunk work destroyed).
            victims.sort_by(|&a, &b| {
                let sa = world.vms[a].history.intervals().last().map(|iv| iv.start).unwrap_or(0.0);
                let sb = world.vms[b].history.intervals().last().map(|iv| iv.start).unwrap_or(0.0);
                sb.partial_cmp(&sa).unwrap()
            });
        }
        VictimPolicy::SmallestFirst => {
            victims.sort_by(|&a, &b| {
                let ma = world.vms[a].spec.total_mips();
                let mb = world.vms[b].spec.total_mips();
                ma.partial_cmp(&mb).unwrap()
            });
        }
    }
}

/// Minimal prefix of `victim_order` whose removal makes `vm` fit on
/// `host`; `None` if even clearing all interruptible spots is not enough.
/// Allocation-free except for the returned victim set on success; the
/// caller supplies reusable [`VictimScratch`] buffers.
pub fn select_victims_with(
    world: &World,
    host: &Host,
    vm: VmId,
    now: f64,
    policy: VictimPolicy,
    scratch: &mut VictimScratch,
) -> Option<Vec<VmId>> {
    let vm_ref = &world.vms[vm];
    let VictimScratch { order, chosen } = scratch;
    victim_order_into(world, host, now, policy, order);
    if order.is_empty() {
        return None;
    }
    chosen.clear();
    for &v in order.iter() {
        chosen.push(v);
        if world.fits_with_clearing(host, vm_ref, chosen) {
            return Some(chosen.clone());
        }
    }
    None
}

/// Convenience wrapper around [`select_victims_with`] with throwaway
/// scratch buffers.
pub fn select_victims(
    world: &World,
    host: &Host,
    vm: VmId,
    now: f64,
    policy: VictimPolicy,
) -> Option<Vec<VmId>> {
    select_victims_with(world, host, vm, now, policy, &mut VictimScratch::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infra::HostSpec;
    use crate::vm::{SpotConfig, Vm, VmSpec, VmState};

    /// World with one 8-PE host carrying `n` running 2-PE spot VMs started
    /// at increasing times.
    fn setup(n: usize) -> (World, usize) {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        let h = w.add_host(dc, HostSpec::new(8, 1000.0, 65_536.0, 40_000.0, 1_600_000.0), 0.0);
        for i in 0..n {
            let cfg = SpotConfig::terminate().with_min_running(0.0);
            let id = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 2), cfg));
            w.commit_vm(h, id);
            w.transition_vm(id, VmState::Running);
            w.vms[id].host = Some(h);
            w.vms[id].history.record_start(h, i as f64 * 10.0);
        }
        (w, h)
    }

    #[test]
    fn list_order_takes_allocation_order() {
        let (w, h) = setup(3);
        let order = victim_order(&w, &w.hosts[h], 100.0, VictimPolicy::ListOrder);
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn youngest_reverses_start_order() {
        let (w, h) = setup(3);
        let order = victim_order(&w, &w.hosts[h], 100.0, VictimPolicy::Youngest);
        assert_eq!(order, vec![2, 1, 0]);
    }

    #[test]
    fn selects_minimal_prefix() {
        let (mut w, h) = setup(4); // 8 PEs all used by 4x2-PE spots
        // the incoming on-demand VM needing 4 PEs
        let vm = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)));
        let victims = select_victims(&w, &w.hosts[h], vm, 100.0, VictimPolicy::ListOrder).unwrap();
        assert_eq!(victims, vec![0, 1]); // 2 spots x 2 PEs free exactly 4
    }

    #[test]
    fn none_when_clearing_insufficient() {
        let (mut w, h) = setup(2); // only 4 PEs clearable, 4 free
        let vm = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 9))); // > host total
        assert!(select_victims(&w, &w.hosts[h], vm, 100.0, VictimPolicy::ListOrder).is_none());
    }

    #[test]
    fn min_runtime_blocks_victims() {
        let (mut w, h) = setup(0);
        let cfg = SpotConfig::terminate().with_min_running(1_000.0);
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 8), cfg));
        w.commit_vm(h, sp);
        w.transition_vm(sp, VmState::Running);
        w.vms[sp].history.record_start(h, 0.0);
        let vm = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)));
        // At t=10 the spot has not met its min running time yet.
        assert!(select_victims(&w, &w.hosts[h], vm, 10.0, VictimPolicy::ListOrder).is_none());
        // At t=2000 it has.
        assert!(select_victims(&w, &w.hosts[h], vm, 2000.0, VictimPolicy::ListOrder).is_some());
    }
}
