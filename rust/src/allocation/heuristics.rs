//! Baseline heuristics (paper §II-D: First Fit, Next Fit, Best Fit, Worst
//! Fit; First-Fit is the CloudSim Plus policy the evaluation compares
//! against, §VII-E).
//!
//! All baselines share [`preempt::select_victims_with`] for the
//! spot-preemption path. Since the placement index landed they run on
//! [`World`]'s indexed queries (free-PE buckets + spot-host set) instead
//! of scanning `active_hosts()` end to end; every policy keeps a
//! `scan_mode` switch that restores the pre-index linear scan - the
//! parity tests pin both modes to identical decisions and the decision
//! benches use scan mode as the baseline.

use super::policy::AllocationPolicy;
use super::preempt::{self, VictimScratch};
use crate::engine::config::VictimPolicy;
use crate::engine::world::World;
use crate::infra::{Host, HostId};
use crate::vm::{Vm, VmId};

fn fits(host: &Host, vm: &Vm) -> bool {
    host.fits(vm.spec.pes, vm.spec.ram, vm.spec.bw, vm.spec.storage)
}

/// Generic preemption scan: first host (in id order) where clearing
/// interruptible spots makes room. The indexed path enumerates only
/// hosts that actually carry spot VMs - hosts without spots can never
/// yield victims, so the result is identical to the full scan.
fn scan_preemption(
    world: &World,
    vm: VmId,
    now: f64,
    victim_policy: VictimPolicy,
    scan_mode: bool,
    scratch: &mut VictimScratch,
) -> Option<(HostId, Vec<VmId>)> {
    // Never preempt spots to place another spot (paper §V-C: spot VMs are
    // interrupted when *on-demand* requests cannot be fulfilled).
    if world.vms[vm].is_spot() {
        return None;
    }
    if scan_mode {
        for host in world.active_hosts() {
            if let Some(victims) =
                preempt::select_victims_with(world, host, vm, now, victim_policy, scratch)
            {
                return Some((host.id, victims));
            }
        }
    } else {
        for id in world.spot_host_ids() {
            let host = &world.hosts[id];
            if let Some(victims) =
                preempt::select_victims_with(world, host, vm, now, victim_policy, scratch)
            {
                return Some((id, victims));
            }
        }
    }
    None
}

macro_rules! baseline_policy {
    ($(#[$doc:meta])* $name:ident, $label:literal, $indexed:ident, $scanned:ident) => {
        $(#[$doc])*
        pub struct $name {
            victim_policy: VictimPolicy,
            decisions: u64,
            scan_mode: bool,
            scratch: VictimScratch,
        }

        impl $name {
            pub fn new() -> Self {
                $name {
                    victim_policy: VictimPolicy::ListOrder,
                    decisions: 0,
                    scan_mode: false,
                    scratch: VictimScratch::default(),
                }
            }

            pub fn with_victim_policy(mut self, p: VictimPolicy) -> Self {
                self.victim_policy = p;
                self
            }

            /// Use the pre-index linear scan instead of the placement
            /// index (parity oracle / bench baseline; decisions are
            /// identical by construction and pinned by tests).
            #[doc(hidden)]
            pub fn with_scan_mode(mut self, scan: bool) -> Self {
                self.scan_mode = scan;
                self
            }
        }

        impl Default for $name {
            fn default() -> Self {
                Self::new()
            }
        }

        impl AllocationPolicy for $name {
            fn name(&self) -> &'static str {
                $label
            }

            fn select_host(&mut self, world: &World, vm: VmId, _now: f64) -> Option<HostId> {
                self.decisions += 1;
                let v = &world.vms[vm];
                if self.scan_mode {
                    world.$scanned(v)
                } else {
                    world.$indexed(v)
                }
            }

            fn select_preemption(
                &mut self,
                world: &World,
                vm: VmId,
                now: f64,
            ) -> Option<(HostId, Vec<VmId>)> {
                scan_preemption(
                    world,
                    vm,
                    now,
                    self.victim_policy,
                    self.scan_mode,
                    &mut self.scratch,
                )
            }

            fn decisions(&self) -> u64 {
                self.decisions
            }
        }
    };
}

baseline_policy!(
    /// First-Fit: first active host (id order) with room.
    FirstFit,
    "first-fit",
    first_fit_host,
    first_fit_host_scan
);

baseline_policy!(
    /// Best-Fit: feasible host with the *fewest* free PEs (tightest pack).
    BestFit,
    "best-fit",
    best_fit_host,
    best_fit_host_scan
);

baseline_policy!(
    /// Worst-Fit: feasible host with the *most* free PEs (load spreading).
    WorstFit,
    "worst-fit",
    worst_fit_host,
    worst_fit_host_scan
);

/// Round-Robin: rotate a cursor over hosts, take the first feasible one.
/// (Cursor semantics are inherently positional, so it keeps the linear
/// probe; only its preemption path uses the spot-host index.)
pub struct RoundRobin {
    cursor: usize,
    victim_policy: VictimPolicy,
    decisions: u64,
    scratch: VictimScratch,
}

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin {
            cursor: 0,
            victim_policy: VictimPolicy::ListOrder,
            decisions: 0,
            scratch: VictimScratch::default(),
        }
    }

    pub fn with_victim_policy(mut self, p: VictimPolicy) -> Self {
        self.victim_policy = p;
        self
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl AllocationPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn select_host(&mut self, world: &World, vm: VmId, _now: f64) -> Option<HostId> {
        self.decisions += 1;
        let n = world.hosts.len();
        if n == 0 {
            return None;
        }
        let v = &world.vms[vm];
        for off in 0..n {
            let idx = (self.cursor + off) % n;
            let h = &world.hosts[idx];
            if fits(h, v) {
                self.cursor = (idx + 1) % n;
                return Some(h.id);
            }
        }
        None
    }

    fn select_preemption(
        &mut self,
        world: &World,
        vm: VmId,
        now: f64,
    ) -> Option<(HostId, Vec<VmId>)> {
        scan_preemption(world, vm, now, self.victim_policy, false, &mut self.scratch)
    }

    fn decisions(&self) -> u64 {
        self.decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infra::HostSpec;
    use crate::vm::{SpotConfig, VmSpec, VmState};

    /// Three hosts with 2/4/8 free PEs; returns (world, incoming vm id).
    fn setup() -> (World, VmId) {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        for pes in [2u32, 4, 8] {
            w.add_host(dc, HostSpec::new(pes, 1000.0, 65_536.0, 40_000.0, 1_600_000.0), 0.0);
        }
        let vm = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        (w, vm)
    }

    #[test]
    fn first_fit_takes_lowest_id() {
        let (w, vm) = setup();
        assert_eq!(FirstFit::new().select_host(&w, vm, 0.0), Some(0));
        assert_eq!(FirstFit::new().with_scan_mode(true).select_host(&w, vm, 0.0), Some(0));
    }

    #[test]
    fn best_fit_takes_tightest() {
        let (w, vm) = setup();
        assert_eq!(BestFit::new().select_host(&w, vm, 0.0), Some(0)); // 2 free PEs
        assert_eq!(BestFit::new().with_scan_mode(true).select_host(&w, vm, 0.0), Some(0));
    }

    #[test]
    fn worst_fit_takes_emptiest() {
        let (w, vm) = setup();
        assert_eq!(WorstFit::new().select_host(&w, vm, 0.0), Some(2)); // 8 free PEs
        assert_eq!(WorstFit::new().with_scan_mode(true).select_host(&w, vm, 0.0), Some(2));
    }

    #[test]
    fn round_robin_rotates() {
        let (mut w, vm) = setup();
        let mut rr = RoundRobin::new();
        assert_eq!(rr.select_host(&w, vm, 0.0), Some(0));
        // Simulate the placement so host 0 fills up.
        w.commit_vm(0, vm);
        let vm2 = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        assert_eq!(rr.select_host(&w, vm2, 0.0), Some(1));
    }

    #[test]
    fn skips_infeasible_hosts() {
        let (w, _) = setup();
        let mut w = w;
        let big = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 6)));
        assert_eq!(FirstFit::new().select_host(&w, big, 0.0), Some(2));
        let huge = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 16)));
        assert_eq!(FirstFit::new().select_host(&w, huge, 0.0), None);
    }

    #[test]
    fn preemption_only_for_on_demand() {
        let (mut w, _) = setup();
        // Fill host 0 with an interruptible spot.
        let cfg = SpotConfig::terminate().with_min_running(0.0);
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 2), cfg));
        w.commit_vm(0, sp);
        w.transition_vm(sp, VmState::Running);
        w.vms[sp].history.record_start(0, 0.0);
        // Fill hosts 1 and 2 completely with on-demand.
        for h in [1usize, 2] {
            let pes = w.hosts[h].spec.pes;
            let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, pes)));
            w.commit_vm(h, od);
            w.transition_vm(od, VmState::Running);
        }
        let od_new = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let spot_new = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 2), cfg));
        let mut ff = FirstFit::new();
        // On-demand may preempt the spot on host 0.
        let (h, victims) = ff.select_preemption(&w, od_new, 10.0).unwrap();
        assert_eq!((h, victims), (0, vec![sp]));
        // The indexed and scanned preemption scans agree.
        let mut ff_scan = FirstFit::new().with_scan_mode(true);
        assert_eq!(ff_scan.select_preemption(&w, od_new, 10.0), Some((0, vec![sp])));
        // A spot VM must never preempt.
        assert!(ff.select_preemption(&w, spot_new, 10.0).is_none());
    }
}
