//! Baseline heuristics (paper §II-D: First Fit, Next Fit, Best Fit, Worst
//! Fit; First-Fit is the CloudSim Plus policy the evaluation compares
//! against, §VII-E).
//!
//! All baselines share [`preempt::select_victims`] for the spot-preemption
//! path, scanning hosts in their own characteristic order.

use super::policy::AllocationPolicy;
use super::preempt;
use crate::engine::config::VictimPolicy;
use crate::engine::world::World;
use crate::infra::{Host, HostId};
use crate::vm::{Vm, VmId};

fn fits(host: &Host, vm: &Vm) -> bool {
    host.fits(vm.spec.pes, vm.spec.ram, vm.spec.bw, vm.spec.storage)
}

/// Generic preemption scan: first host (in id order) where clearing
/// interruptible spots makes room.
fn scan_preemption(
    world: &World,
    vm: VmId,
    now: f64,
    victim_policy: VictimPolicy,
) -> Option<(HostId, Vec<VmId>)> {
    // Never preempt spots to place another spot (paper §V-C: spot VMs are
    // interrupted when *on-demand* requests cannot be fulfilled).
    if world.vms[vm].is_spot() {
        return None;
    }
    for host in world.active_hosts() {
        if let Some(victims) = preempt::select_victims(world, host, vm, now, victim_policy) {
            return Some((host.id, victims));
        }
    }
    None
}

/// First-Fit: first active host (id order) with room.
pub struct FirstFit {
    victim_policy: VictimPolicy,
    decisions: u64,
}

impl FirstFit {
    pub fn new() -> Self {
        FirstFit { victim_policy: VictimPolicy::ListOrder, decisions: 0 }
    }

    pub fn with_victim_policy(mut self, p: VictimPolicy) -> Self {
        self.victim_policy = p;
        self
    }
}

impl Default for FirstFit {
    fn default() -> Self {
        Self::new()
    }
}

impl AllocationPolicy for FirstFit {
    fn name(&self) -> &'static str {
        "first-fit"
    }

    fn select_host(&mut self, world: &World, vm: VmId, _now: f64) -> Option<HostId> {
        self.decisions += 1;
        let v = &world.vms[vm];
        world.active_hosts().find(|h| fits(h, v)).map(|h| h.id)
    }

    fn select_preemption(
        &mut self,
        world: &World,
        vm: VmId,
        now: f64,
    ) -> Option<(HostId, Vec<VmId>)> {
        scan_preemption(world, vm, now, self.victim_policy)
    }

    fn decisions(&self) -> u64 {
        self.decisions
    }
}

/// Best-Fit: feasible host with the *fewest* free PEs (tightest pack).
pub struct BestFit {
    victim_policy: VictimPolicy,
    decisions: u64,
}

impl BestFit {
    pub fn new() -> Self {
        BestFit { victim_policy: VictimPolicy::ListOrder, decisions: 0 }
    }
}

impl Default for BestFit {
    fn default() -> Self {
        Self::new()
    }
}

impl AllocationPolicy for BestFit {
    fn name(&self) -> &'static str {
        "best-fit"
    }

    fn select_host(&mut self, world: &World, vm: VmId, _now: f64) -> Option<HostId> {
        self.decisions += 1;
        let v = &world.vms[vm];
        world
            .active_hosts()
            .filter(|h| fits(h, v))
            .min_by_key(|h| h.free_pes())
            .map(|h| h.id)
    }

    fn select_preemption(
        &mut self,
        world: &World,
        vm: VmId,
        now: f64,
    ) -> Option<(HostId, Vec<VmId>)> {
        scan_preemption(world, vm, now, self.victim_policy)
    }

    fn decisions(&self) -> u64 {
        self.decisions
    }
}

/// Worst-Fit: feasible host with the *most* free PEs (load spreading).
pub struct WorstFit {
    victim_policy: VictimPolicy,
    decisions: u64,
}

impl WorstFit {
    pub fn new() -> Self {
        WorstFit { victim_policy: VictimPolicy::ListOrder, decisions: 0 }
    }
}

impl Default for WorstFit {
    fn default() -> Self {
        Self::new()
    }
}

impl AllocationPolicy for WorstFit {
    fn name(&self) -> &'static str {
        "worst-fit"
    }

    fn select_host(&mut self, world: &World, vm: VmId, _now: f64) -> Option<HostId> {
        self.decisions += 1;
        let v = &world.vms[vm];
        world
            .active_hosts()
            .filter(|h| fits(h, v))
            .max_by_key(|h| h.free_pes())
            .map(|h| h.id)
    }

    fn select_preemption(
        &mut self,
        world: &World,
        vm: VmId,
        now: f64,
    ) -> Option<(HostId, Vec<VmId>)> {
        scan_preemption(world, vm, now, self.victim_policy)
    }

    fn decisions(&self) -> u64 {
        self.decisions
    }
}

/// Round-Robin: rotate a cursor over hosts, take the first feasible one.
pub struct RoundRobin {
    cursor: usize,
    victim_policy: VictimPolicy,
    decisions: u64,
}

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin { cursor: 0, victim_policy: VictimPolicy::ListOrder, decisions: 0 }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl AllocationPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn select_host(&mut self, world: &World, vm: VmId, _now: f64) -> Option<HostId> {
        self.decisions += 1;
        let n = world.hosts.len();
        if n == 0 {
            return None;
        }
        let v = &world.vms[vm];
        for off in 0..n {
            let idx = (self.cursor + off) % n;
            let h = &world.hosts[idx];
            if fits(h, v) {
                self.cursor = (idx + 1) % n;
                return Some(h.id);
            }
        }
        None
    }

    fn select_preemption(
        &mut self,
        world: &World,
        vm: VmId,
        now: f64,
    ) -> Option<(HostId, Vec<VmId>)> {
        scan_preemption(world, vm, now, self.victim_policy)
    }

    fn decisions(&self) -> u64 {
        self.decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infra::HostSpec;
    use crate::vm::{SpotConfig, VmSpec, VmState};

    /// Three hosts with 2/4/8 free PEs; returns (world, incoming vm id).
    fn setup() -> (World, VmId) {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        for pes in [2u32, 4, 8] {
            w.add_host(dc, HostSpec::new(pes, 1000.0, 65_536.0, 40_000.0, 1_600_000.0), 0.0);
        }
        let vm = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        (w, vm)
    }

    #[test]
    fn first_fit_takes_lowest_id() {
        let (w, vm) = setup();
        assert_eq!(FirstFit::new().select_host(&w, vm, 0.0), Some(0));
    }

    #[test]
    fn best_fit_takes_tightest() {
        let (w, vm) = setup();
        assert_eq!(BestFit::new().select_host(&w, vm, 0.0), Some(0)); // 2 free PEs
    }

    #[test]
    fn worst_fit_takes_emptiest() {
        let (w, vm) = setup();
        assert_eq!(WorstFit::new().select_host(&w, vm, 0.0), Some(2)); // 8 free PEs
    }

    #[test]
    fn round_robin_rotates() {
        let (mut w, vm) = setup();
        let mut rr = RoundRobin::new();
        assert_eq!(rr.select_host(&w, vm, 0.0), Some(0));
        // Simulate the placement so host 0 fills up.
        let spec = w.vms[vm].spec;
        w.hosts[0].commit(vm, spec.pes, spec.ram, spec.bw, spec.storage);
        let vm2 = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        assert_eq!(rr.select_host(&w, vm2, 0.0), Some(1));
    }

    #[test]
    fn skips_infeasible_hosts() {
        let (w, _) = setup();
        let mut w = w;
        let big = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 6)));
        assert_eq!(FirstFit::new().select_host(&w, big, 0.0), Some(2));
        let huge = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 16)));
        assert_eq!(FirstFit::new().select_host(&w, huge, 0.0), None);
    }

    #[test]
    fn preemption_only_for_on_demand() {
        let (mut w, _) = setup();
        // Fill host 0 with an interruptible spot.
        let cfg = SpotConfig::terminate().with_min_running(0.0);
        let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 2), cfg));
        let spec = w.vms[sp].spec;
        w.hosts[0].commit(sp, spec.pes, spec.ram, spec.bw, spec.storage);
        w.vms[sp].transition(VmState::Running);
        w.vms[sp].history.record_start(0, 0.0);
        // Fill hosts 1 and 2 completely with on-demand.
        for h in [1usize, 2] {
            let pes = w.hosts[h].spec.pes;
            let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, pes)));
            let spec = w.vms[od].spec;
            w.hosts[h].commit(od, spec.pes, spec.ram, spec.bw, spec.storage);
            w.vms[od].transition(VmState::Running);
        }
        let od_new = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let spot_new = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 2), cfg));
        let mut ff = FirstFit::new();
        // On-demand may preempt the spot on host 0.
        let (h, victims) = ff.select_preemption(&w, od_new, 10.0).unwrap();
        assert_eq!((h, victims), (0, vec![sp]));
        // A spot VM must never preempt.
        assert!(ff.select_preemption(&w, spot_new, 10.0).is_none());
    }
}
