//! VM allocation policies (paper §II-D, §V-E(b), §VI).
//!
//! - [`policy::AllocationPolicy`]: the `VmAllocationPolicyAbstract` role,
//!   extended with spot preemption (`DynamicAllocation`, §V-E(b)).
//! - [`heuristics`]: First-Fit / Best-Fit / Worst-Fit / Round-Robin
//!   baselines (First-Fit is the paper's comparison baseline, §VII-E).
//! - [`hlem`]: HLEM-VMP (Eqs. 1-9) and its spot-load-adjusted variant
//!   (Eqs. 10-11) - the paper's §VI contribution.
//! - [`scorer`]: the host-scoring backends (pure-rust oracle and the
//!   PJRT-executed AOT artifact built from the L1 pallas kernel).
//! - [`preempt`]: shared spot-victim selection (the `spotAllocation` /
//!   `terminationBehavior` logic of `DynamicAllocation`).

pub mod heuristics;
pub mod hlem;
pub mod policy;
pub mod preempt;
pub mod scorer;

pub use heuristics::{BestFit, FirstFit, RoundRobin, WorstFit};
pub use hlem::{HlemConfig, HlemVmp};
pub use policy::AllocationPolicy;
pub use scorer::{HostScorer, RustScorer, ScoreInput};
