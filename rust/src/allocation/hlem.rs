//! HLEM-VMP (Heuristic-based Load balancing and Energy-aware VM Placement,
//! paper §VI / Algorithm 1) and its spot-load-adjusted variant (§VI-C).
//!
//! Three phases per placement decision:
//!
//! 1. **Host filtering**: active hosts with free capacity in all four
//!    dimensions, plus the RsDiff CPU-similarity filter (Eqs. 1-2).
//!    Following Algorithm 1 we additionally build the
//!    "feasible-if-spot-cleared" list (`FilterPHWithSpotClr`), consulted
//!    only for on-demand VMs when the plain list is empty.
//! 2. **Host load evaluation**: entropy-weighted scoring (Eqs. 3-9),
//!    delegated to a [`HostScorer`] backend; the adjusted variant
//!    additionally applies the spot-load factor (Eqs. 10-11).
//! 3. **Host selection**: highest score wins. The paper omits the original
//!    algorithm's energy check and so do we (§VI-A).
//!
//! Since the placement index landed, phase 1 enumerates candidates from
//! the world's free-PE buckets (id-ascending, so the entropy-weight float
//! summation order - and therefore every score - is bit-identical to the
//! old full scan), and the per-host spot-usage vectors are O(1) reads
//! instead of per-candidate VM-list walks. `scan_mode` restores the
//! pre-index scans for the parity tests and the decision benches.
//!
//! Documented deviations (DESIGN.md §4): when the RsDiff filter empties an
//! otherwise-feasible candidate list we fall back to the unfiltered list
//! (otherwise small VMs become unplaceable on loaded clusters); the sign
//! convention of alpha is negative-penalizes (the paper calls alpha a
//! penalty factor but writes a score-increasing product).

use super::policy::AllocationPolicy;
use super::preempt::{self, VictimScratch};
use super::scorer::{HostScorer, RustScorer, ScoreInput, NEG};
use crate::engine::config::VictimPolicy;
use crate::engine::world::World;
use crate::infra::{Host, HostId};
use crate::vm::{Vm, VmId};

/// HLEM-VMP configuration (paper §VI-B "Attributes").
#[derive(Debug, Clone)]
pub struct HlemConfig {
    /// Resource carrying factor `Rc` of Eq. (1). Paper default 0.95.
    pub resource_carrying_factor: f64,
    /// CPU threshold of Eq. (2). Paper default 0.
    pub threshold: f64,
    /// Spot-load factor alpha of Eq. (11). 0 disables the adjustment
    /// (plain HLEM-VMP); the adjusted variant defaults to -0.5.
    pub alpha: f64,
    /// Rank hosts by AHS (adjusted variant) instead of HS.
    pub use_adjusted_score: bool,
    /// Victim ordering for the preemption path.
    pub victim_policy: VictimPolicy,
    /// Disable the RsDiff fallback (strict Eq. 2 behavior; ablation knob).
    pub strict_rsdiff: bool,
}

impl HlemConfig {
    /// Plain HLEM-VMP (paper §VI-B).
    pub fn plain() -> Self {
        HlemConfig {
            resource_carrying_factor: 0.95,
            threshold: 0.0,
            alpha: 0.0,
            use_adjusted_score: false,
            victim_policy: VictimPolicy::ListOrder,
            strict_rsdiff: false,
        }
    }

    /// Spot-load-adjusted HLEM-VMP (paper §VI-C), default alpha = -0.5.
    pub fn adjusted() -> Self {
        HlemConfig { alpha: -0.5, use_adjusted_score: true, ..Self::plain() }
    }

    pub fn with_alpha(mut self, alpha: f64) -> Self {
        self.alpha = alpha;
        self
    }

    pub fn with_victim_policy(mut self, p: VictimPolicy) -> Self {
        self.victim_policy = p;
        self
    }
}

/// The HLEM-VMP allocation policy (`DynamicAllocationHLEM` /
/// `DynamicAllocationHLEMAdjusted` in the paper).
pub struct HlemVmp {
    pub config: HlemConfig,
    scorer: Box<dyn HostScorer>,
    decisions: u64,
    /// Placements that needed the RsDiff fallback (observability).
    pub rsdiff_fallbacks: u64,
    /// Pre-index linear scans instead of the placement index (parity
    /// oracle / bench baseline).
    scan_mode: bool,
    // Scratch buffers reused across decisions (the scoring path runs on
    // every placement; per-call Vec allocation measured ~25% of decision
    // latency - EXPERIMENTS.md SPerf iteration log).
    scratch_caps: Vec<[f64; 4]>,
    scratch_free: Vec<[f64; 4]>,
    scratch_spot: Vec<[f64; 4]>,
    scratch_mask: Vec<bool>,
    scratch_feasible: Vec<HostId>,
    scratch_ids: Vec<HostId>,
    scratch_vms: Vec<VmId>,
    victim_scratch: VictimScratch,
}

impl HlemVmp {
    pub fn new(config: HlemConfig) -> Self {
        Self::with_scorer(config, Box::new(RustScorer::new()))
    }

    pub fn plain() -> Self {
        Self::new(HlemConfig::plain())
    }

    pub fn adjusted() -> Self {
        Self::new(HlemConfig::adjusted())
    }

    /// Use a custom scoring backend (e.g. the PJRT artifact executor).
    pub fn with_scorer(config: HlemConfig, scorer: Box<dyn HostScorer>) -> Self {
        HlemVmp {
            config,
            scorer,
            decisions: 0,
            rsdiff_fallbacks: 0,
            scan_mode: false,
            scratch_caps: Vec::new(),
            scratch_free: Vec::new(),
            scratch_spot: Vec::new(),
            scratch_mask: Vec::new(),
            scratch_feasible: Vec::new(),
            scratch_ids: Vec::new(),
            scratch_vms: Vec::new(),
            victim_scratch: VictimScratch::default(),
        }
    }

    /// Use the pre-index linear scans (parity oracle / bench baseline).
    #[doc(hidden)]
    pub fn with_scan_mode(mut self, scan: bool) -> Self {
        self.scan_mode = scan;
        self
    }

    pub fn scorer_name(&self) -> &'static str {
        self.scorer.name()
    }

    /// RsDiff filter (Eqs. 1-2): `R_j - U_i * Rc > Thr_cpu` with `R_j` the
    /// VM's CPU request and `U_i` the host's utilization, both as fractions
    /// of the host's CPU capacity.
    fn rsdiff_ok(&self, host: &Host, vm: &Vm) -> bool {
        let total = host.spec.total_mips();
        if total <= 0.0 {
            return false;
        }
        let r_j = vm.spec.total_mips() / total;
        let u_i = host.cpu_utilization();
        r_j - u_i * self.config.resource_carrying_factor > self.config.threshold
    }

    /// Phase 1: fill `self.scratch_ids` with the candidate list (feasible
    /// now, RsDiff-filtered with fallback), ascending by host id.
    fn filter_hosts(&mut self, world: &World, vm: &Vm) {
        let mut feasible = std::mem::take(&mut self.scratch_feasible);
        if self.scan_mode {
            world.feasible_host_ids_scan(vm, &mut feasible);
        } else {
            world.feasible_host_ids(vm, &mut feasible);
        }
        let mut ids = std::mem::take(&mut self.scratch_ids);
        ids.clear();
        ids.extend(feasible.iter().copied().filter(|&id| self.rsdiff_ok(&world.hosts[id], vm)));
        if ids.is_empty() && !feasible.is_empty() && !self.config.strict_rsdiff {
            self.rsdiff_fallbacks += 1;
            ids.extend(feasible.iter().copied());
        }
        self.scratch_feasible = feasible;
        self.scratch_ids = ids;
    }

    /// Phases 2-3 over an explicit candidate list (host ids in the scan
    /// order): score and pick the best.
    fn best_of(&mut self, world: &World, candidates: &[HostId]) -> Option<HostId> {
        if candidates.is_empty() {
            return None;
        }
        self.scratch_caps.clear();
        self.scratch_free.clear();
        self.scratch_spot.clear();
        self.scratch_mask.clear();
        for &id in candidates {
            let h = &world.hosts[id];
            self.scratch_caps.push(h.capacity_vec());
            self.scratch_free.push(h.free_vec());
            self.scratch_spot.push(if self.scan_mode {
                world.spot_used_vec_scan(h)
            } else {
                world.spot_used_vec(h)
            });
            self.scratch_mask.push(true);
        }
        let (hs, ahs) = self.scorer.scores(&ScoreInput {
            caps: &self.scratch_caps,
            free: &self.scratch_free,
            spot_used: &self.scratch_spot,
            mask: &self.scratch_mask,
            alpha: self.config.alpha,
        });
        let scores = if self.config.use_adjusted_score { &ahs } else { &hs };
        let mut best: Option<(f64, HostId)> = None;
        for (i, &s) in scores.iter().enumerate() {
            if s <= NEG {
                continue;
            }
            // Deterministic tie-break on host id.
            let better = match best {
                None => true,
                Some((bs, bid)) => s > bs || (s == bs && candidates[i] < bid),
            };
            if better {
                best = Some((s, candidates[i]));
            }
        }
        best.map(|(_, id)| id)
    }
}

impl AllocationPolicy for HlemVmp {
    fn name(&self) -> &'static str {
        if self.config.use_adjusted_score {
            "hlem-vmp-adjusted"
        } else {
            "hlem-vmp"
        }
    }

    fn select_host(&mut self, world: &World, vm: VmId, _now: f64) -> Option<HostId> {
        self.decisions += 1;
        let v = &world.vms[vm];
        self.filter_hosts(world, v);
        let ids = std::mem::take(&mut self.scratch_ids);
        let best = self.best_of(world, &ids);
        self.scratch_ids = ids;
        best
    }

    fn select_preemption(
        &mut self,
        world: &World,
        vm: VmId,
        now: f64,
    ) -> Option<(HostId, Vec<VmId>)> {
        let v = &world.vms[vm];
        if v.is_spot() {
            return None; // spots never preempt (paper §V-C)
        }
        // Algorithm 1 line 4: PHCandidateListClrSpot - hosts feasible if
        // their interruptible spot load were cleared. Only hosts carrying
        // spot VMs can qualify, so the indexed path enumerates the
        // spot-host set instead of every active host.
        let mut spots = std::mem::take(&mut self.scratch_vms);
        let mut cand = std::mem::take(&mut self.scratch_feasible);
        cand.clear();
        if self.scan_mode {
            for h in world.active_hosts() {
                world.interruptible_spots_into(h, now, &mut spots);
                if !spots.is_empty() && world.fits_with_clearing(h, v, &spots) {
                    cand.push(h.id);
                }
            }
        } else {
            for id in world.spot_host_ids() {
                let h = &world.hosts[id];
                world.interruptible_spots_into(h, now, &mut spots);
                if !spots.is_empty() && world.fits_with_clearing(h, v, &spots) {
                    cand.push(id);
                }
            }
        }
        spots.clear();
        self.scratch_vms = spots;
        // Rank the clearable hosts by the same score and take the best one
        // for which a minimal victim set exists.
        let mut result = None;
        while !cand.is_empty() {
            let Some(best) = self.best_of(world, &cand) else {
                break;
            };
            let host = &world.hosts[best];
            if let Some(victims) = preempt::select_victims_with(
                world,
                host,
                vm,
                now,
                self.config.victim_policy,
                &mut self.victim_scratch,
            ) {
                result = Some((best, victims));
                break;
            }
            cand.retain(|&h| h != best);
        }
        self.scratch_feasible = cand;
        result
    }

    fn decisions(&self) -> u64 {
        self.decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infra::HostSpec;
    use crate::vm::{SpotConfig, Vm, VmSpec, VmState};

    fn spec_host(pes: u32) -> HostSpec {
        HostSpec::new(pes, 1000.0, 65_536.0, 40_000.0, 1_600_000.0)
    }

    fn commit_running(w: &mut World, host: HostId, vm: VmId, start: f64) {
        w.commit_vm(host, vm);
        w.transition_vm(vm, VmState::Running);
        w.vms[vm].host = Some(host);
        w.vms[vm].history.record_start(host, start);
    }

    #[test]
    fn picks_emptiest_of_identical_hosts() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        for _ in 0..3 {
            w.add_host(dc, spec_host(8), 0.0);
        }
        // Load host 0 heavily, host 1 lightly.
        let a = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 6)));
        commit_running(&mut w, 0, a, 0.0);
        let b = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        commit_running(&mut w, 1, b, 0.0);

        let vm = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        let got = HlemVmp::plain().select_host(&w, vm, 1.0);
        assert_eq!(got, Some(2)); // untouched host has max free capacity
        let scanned = HlemVmp::plain().with_scan_mode(true).select_host(&w, vm, 1.0);
        assert_eq!(got, scanned);
    }

    #[test]
    fn adjusted_variant_avoids_spot_heavy_host() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        w.add_host(dc, spec_host(16), 0.0);
        w.add_host(dc, spec_host(16), 0.0);
        // Equal free capacity, but host 0 carries spot VMs and host 1
        // carries on-demand VMs of the same size.
        let cfg = SpotConfig::hibernate().with_min_running(0.0);
        let s = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 4), cfg));
        commit_running(&mut w, 0, s, 0.0);
        let o = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)));
        commit_running(&mut w, 1, o, 0.0);

        let vm = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 2), cfg));
        // Plain HLEM is indifferent (free vectors identical) -> ties to
        // lowest id = 0.
        assert_eq!(HlemVmp::plain().select_host(&w, vm, 1.0), Some(0));
        // Adjusted penalizes host 0 for its spot load.
        assert_eq!(HlemVmp::adjusted().select_host(&w, vm, 1.0), Some(1));
        assert_eq!(HlemVmp::adjusted().with_scan_mode(true).select_host(&w, vm, 1.0), Some(1));
    }

    #[test]
    fn rsdiff_fallback_keeps_feasible_hosts() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        w.add_host(dc, spec_host(8), 0.0);
        // Fill to 7/8 PEs: utilization 0.875; a 1-PE VM has R_j = 0.125
        // < 0.875*0.95, so strict RsDiff rejects the host.
        let a = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 7)));
        commit_running(&mut w, 0, a, 0.0);
        let vm = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 1)));

        let mut strict = HlemVmp::new(HlemConfig { strict_rsdiff: true, ..HlemConfig::plain() });
        assert_eq!(strict.select_host(&w, vm, 1.0), None);

        let mut lenient = HlemVmp::plain();
        assert_eq!(lenient.select_host(&w, vm, 1.0), Some(0));
        assert_eq!(lenient.rsdiff_fallbacks, 1);
    }

    #[test]
    fn preemption_ranks_clearable_hosts() {
        let mut w = World::new();
        let dc = w.add_datacenter("dc", 1.0);
        w.add_host(dc, spec_host(8), 0.0);
        w.add_host(dc, spec_host(4), 0.0);
        let cfg = SpotConfig::terminate().with_min_running(0.0);
        // Host 0: 8 PEs of spot; host 1 (4 PEs total): 2 PEs of spot.
        let s0 = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 8), cfg));
        commit_running(&mut w, 0, s0, 0.0);
        let s1 = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, 2), cfg));
        commit_running(&mut w, 1, s1, 0.0);

        // Incoming on-demand VM needs 8 PEs: only host 0 can be cleared
        // enough (host 1 tops out at 4 PEs even fully cleared).
        let vm = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 8)));
        let (host, victims) = HlemVmp::plain().select_preemption(&w, vm, 10.0).unwrap();
        assert_eq!((host, victims), (0, vec![s0]));

        // A 4-PE on-demand VM: both hosts clearable; host 1 has more
        // residual free capacity (2 free PEs vs 0) so it ranks higher.
        let vm2 = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 4)));
        let (host2, victims2) = HlemVmp::plain().select_preemption(&w, vm2, 10.0).unwrap();
        assert_eq!(host2, 1);
        assert_eq!(victims2, vec![s1]);
        // Scan mode agrees.
        let scanned = HlemVmp::plain().with_scan_mode(true).select_preemption(&w, vm2, 10.0);
        assert_eq!(scanned, Some((1, vec![s1])));
    }

    #[test]
    fn empty_world_yields_none() {
        let w = World::new();
        let mut p = HlemVmp::plain();
        // No hosts and no VM registered: guard against panics on empty
        // candidate sets by querying a VM-less world directly.
        assert!(p.best_of(&w, &[]).is_none());
    }
}
