//! Dynamic spot-price market substrate (the paper's core premise):
//! spot interruptions arise from *price dynamics*, not fixed schedules.
//!
//! The price process is a seeded Ornstein-Uhlenbeck mean-reverting walk
//! with a daily periodic component, discretized on a fixed 60 s tick
//! grid (exact AR(1) discretization, unconditionally stable):
//!
//! ```text
//! mu(t)    = MEAN * (1 + amp * sin(2*pi*t / 86400))
//! X_{k+1}  = mu_{k+1} + (X_k - mu_k) * a + vol * sqrt(1 - a^2) * xi_k
//! a        = exp(-theta * TICK)
//! ```
//!
//! Prices are normalized to an on-demand price of 1.0 $/PE-hour; the
//! per-VM bid level is `on-demand price x bid margin`. Like the chaos
//! engine, the whole path is **compiled up front** into a
//! [`MarketSchedule`] - a pure function of `(spec, seed, horizon)` - and
//! [`apply`] only schedules the pre-computed bid-crossing events into
//! the DES queue. The core event loop stays untouched, so artifacts are
//! byte-identical at any `--threads`/`--workers` count.
//!
//! An *upward* crossing (price rises above the bid) out-bids every
//! currently interruptible spot VM and feeds the existing interruption
//! lifecycle (`vm/spot.rs` warning -> hibernate/terminate paths); while
//! the price stays above the bid, spot placement requests are held
//! (out-bid capacity is unavailable, however idle the hosts are). A
//! *downward* crossing lifts the hold and drains the broker retry queue
//! so hibernated spots resume on the again-affordable capacity. Cost
//! accounting
//! (spot $ vs on-demand $, savings ratio, mean/max price paid)
//! integrates the piecewise-constant path over each spot VM's host
//! intervals at report time (`engine::report::MarketStats`).

use std::sync::Arc;

use crate::core::EntityId;
use crate::engine::{Engine, Tag};
use crate::stats::{Dist, Rng};

/// Normalized on-demand price, $ per PE-hour. All spot prices and bids
/// are expressed relative to this.
pub const ON_DEMAND_PRICE: f64 = 1.0;
/// Long-run mean of the spot price as a fraction of the on-demand price
/// (clouds historically clear spot around 30-70% off on-demand).
pub const SPOT_MEAN_RATIO: f64 = 0.4;
/// Price-path discretization step, seconds (one market tick a minute).
pub const TICK_SECS: f64 = 60.0;
/// Prices never fall below this floor (keeps costs strictly positive).
pub const PRICE_FLOOR: f64 = 0.001;
/// Period of the daily demand cycle, seconds.
pub const DAY_SECS: f64 = 86_400.0;

/// Default stationary volatility (std-dev of the OU process, $/PE-hour).
pub const DEFAULT_VOLATILITY: f64 = 0.05;
/// Default mean-reversion rate theta, 1/seconds (time constant ~83 min).
pub const DEFAULT_MEAN_REVERSION: f64 = 2e-4;
/// Default daily periodic amplitude, fraction of the long-run mean.
pub const DEFAULT_DAILY_AMPLITUDE: f64 = 0.25;
/// Default bid level as a fraction of the on-demand price.
pub const DEFAULT_BID_MARGIN: f64 = 0.5;

/// Derived-stream family tag for price paths (chaos uses 1).
const FAMILY_PRICE: u64 = 2;

/// Market price-model parameters for one cell. `None` fields fall back
/// to the `DEFAULT_*` constants; the market is active as soon as any
/// field is set (each parameter is its own `market.*` scenario axis).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct MarketSpec {
    /// Stationary volatility of the OU process ($/PE-hour), >= 0.
    pub volatility: Option<f64>,
    /// Mean-reversion rate theta (1/seconds), > 0.
    pub mean_reversion: Option<f64>,
    /// Daily periodic amplitude (fraction of the mean), in [0, 1].
    pub daily_amplitude: Option<f64>,
    /// Bid level as a fraction of the on-demand price, > 0.
    pub bid_margin: Option<f64>,
}

impl MarketSpec {
    pub const NONE: MarketSpec = MarketSpec {
        volatility: None,
        mean_reversion: None,
        daily_amplitude: None,
        bid_margin: None,
    };

    /// No market axis set: the cell runs without a price process.
    pub fn is_none(&self) -> bool {
        *self == Self::NONE
    }

    pub fn volatility(&self) -> f64 {
        self.volatility.unwrap_or(DEFAULT_VOLATILITY)
    }

    pub fn mean_reversion(&self) -> f64 {
        self.mean_reversion.unwrap_or(DEFAULT_MEAN_REVERSION)
    }

    pub fn daily_amplitude(&self) -> f64 {
        self.daily_amplitude.unwrap_or(DEFAULT_DAILY_AMPLITUDE)
    }

    pub fn bid_margin(&self) -> f64 {
        self.bid_margin.unwrap_or(DEFAULT_BID_MARGIN)
    }
}

/// Exact-round-trip label for a market axis value: Rust's shortest
/// `f64` Display, whose `str::parse` inverse is the identity.
pub fn label_f64(v: f64) -> String {
    format!("{v}")
}

/// The price crossed the bid level at `at`. `up` = the price rose above
/// the bid (spot VMs are out-bid); `!up` = it fell back under.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Crossing {
    pub at: f64,
    pub up: bool,
}

/// A compiled price path: pure function of `(spec, seed, horizon)`.
/// `prices[k]` holds on `[k*tick, (k+1)*tick)`; the last price extends
/// to the end of the run.
#[derive(Debug, Clone, PartialEq)]
pub struct MarketSchedule {
    /// Discretization step, seconds.
    pub tick: f64,
    /// On-demand reference price, $/PE-hour.
    pub od_price: f64,
    /// Bid level, $/PE-hour (`od_price x bid margin`).
    pub bid: f64,
    /// Piecewise-constant spot price, one value per tick.
    pub prices: Vec<f64>,
    /// Pre-computed bid crossings, ascending in time.
    pub crossings: Vec<Crossing>,
}

impl MarketSchedule {
    pub fn is_empty(&self) -> bool {
        self.prices.is_empty()
    }

    /// The spot price in force at time `t`.
    pub fn price_at(&self, t: f64) -> f64 {
        if self.prices.is_empty() {
            return 0.0;
        }
        let k = ((t.max(0.0) / self.tick).floor() as usize).min(self.prices.len() - 1);
        self.prices[k]
    }

    /// Integral of the price over `[start, end)` in price-seconds
    /// (divide by 3600 for $ per PE at 1 PE).
    pub fn cost_over(&self, start: f64, end: f64) -> f64 {
        if self.prices.is_empty() || !(end > start) {
            return 0.0;
        }
        let last = self.prices.len() - 1;
        let mut total = 0.0;
        let mut t = start.max(0.0);
        while t < end {
            let k = ((t / self.tick).floor() as usize).min(last);
            let seg_end =
                if k == last { end } else { ((k as f64 + 1.0) * self.tick).min(end) };
            total += self.prices[k] * (seg_end - t);
            t = seg_end;
        }
        total
    }

    /// Highest tick price overlapping `[start, end)` (0 when degenerate).
    pub fn max_price_over(&self, start: f64, end: f64) -> f64 {
        if self.prices.is_empty() || !(end > start) {
            return 0.0;
        }
        let last = self.prices.len() - 1;
        let k0 = ((start.max(0.0) / self.tick).floor() as usize).min(last);
        let k1 = (((end / self.tick).ceil() as usize).max(k0 + 1) - 1).min(last);
        self.prices[k0..=k1].iter().cloned().fold(0.0, f64::max)
    }
}

fn stream_rng(seed: u64, stream: u64) -> Rng {
    Rng::new(
        seed ^ FAMILY_PRICE.wrapping_mul(0x9e37_79b9_7f4a_7c15)
            ^ stream.wrapping_mul(0xa076_1d64_78bd_642f),
    )
}

/// Compile `spec` into a concrete price path + crossing schedule for one
/// cell. Pure function of its arguments - the sweep prebuild layer
/// caches it per `(substrate, seed, spec)` triple exactly like chaos
/// schedules, and callers at any thread/worker count get the same path.
pub fn compile(spec: &MarketSpec, seed: u64, horizon: f64) -> MarketSchedule {
    let empty = MarketSchedule {
        tick: TICK_SECS,
        od_price: ON_DEMAND_PRICE,
        bid: ON_DEMAND_PRICE * spec.bid_margin(),
        prices: Vec::new(),
        crossings: Vec::new(),
    };
    if spec.is_none() || !horizon.is_finite() || horizon <= 0.0 {
        return empty;
    }
    let vol = spec.volatility();
    let theta = spec.mean_reversion();
    let amp = spec.daily_amplitude();
    let bid = ON_DEMAND_PRICE * spec.bid_margin();

    let mean =
        |t: f64| SPOT_MEAN_RATIO * ON_DEMAND_PRICE * (1.0 + amp * (std::f64::consts::TAU * t / DAY_SECS).sin());
    // Exact AR(1) discretization of the OU process: stable for any
    // theta/tick combination (a in (0, 1]), stationary std-dev = vol.
    let a = (-theta * TICK_SECS).exp();
    let diffusion = vol * (1.0 - a * a).max(0.0).sqrt();
    let noise = Dist::Normal { mu: 0.0, sigma: 1.0 };
    let mut rng = stream_rng(seed, 0);

    let n = ((horizon / TICK_SECS).ceil() as usize).max(1);
    let mut prices = Vec::with_capacity(n);
    let mut x = mean(0.0).max(PRICE_FLOOR);
    prices.push(x);
    for k in 1..n {
        let t0 = (k - 1) as f64 * TICK_SECS;
        let t1 = k as f64 * TICK_SECS;
        x = mean(t1) + (x - mean(t0)) * a + diffusion * noise.sample(&mut rng);
        x = x.max(PRICE_FLOOR);
        prices.push(x);
    }

    let mut crossings = Vec::new();
    if prices[0] > bid {
        crossings.push(Crossing { at: 0.0, up: true });
    }
    for k in 1..n {
        let was = prices[k - 1] > bid;
        let is = prices[k] > bid;
        if is != was {
            crossings.push(Crossing { at: k as f64 * TICK_SECS, up: is });
        }
    }
    MarketSchedule { tick: TICK_SECS, od_price: ON_DEMAND_PRICE, bid, prices, crossings }
}

/// Inject a compiled schedule into an engine: store the path for cost
/// accounting and schedule the pre-computed crossing events. Call after
/// workload submission, before `engine.run()`.
pub fn apply(engine: &mut Engine, sched: &Arc<MarketSchedule>) {
    if sched.is_empty() {
        return;
    }
    engine.market = Some(Arc::clone(sched));
    for (k, c) in sched.crossings.iter().enumerate() {
        engine.sim.schedule_at(
            c.at,
            EntityId::Kernel,
            EntityId::Broker(0),
            Tag::MarketCrossing(k),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::FirstFit;
    use crate::cloudlet::Cloudlet;
    use crate::engine::EngineConfig;
    use crate::infra::HostSpec;
    use crate::vm::{SpotConfig, Vm, VmSpec, VmState};

    fn active_spec() -> MarketSpec {
        MarketSpec {
            volatility: Some(0.1),
            mean_reversion: Some(1e-3),
            daily_amplitude: Some(0.25),
            bid_margin: Some(0.5),
        }
    }

    #[test]
    fn none_spec_compiles_empty() {
        let sched = compile(&MarketSpec::NONE, 1, 86_400.0);
        assert!(sched.is_empty());
        assert!(sched.crossings.is_empty());
        assert_eq!(compile(&active_spec(), 1, 0.0).prices.len(), 0);
    }

    #[test]
    fn compile_is_seed_deterministic() {
        let spec = active_spec();
        let a = compile(&spec, 42, 86_400.0);
        let b = compile(&spec, 42, 86_400.0);
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
        let c = compile(&spec, 43, 86_400.0);
        assert_ne!(format!("{a:?}"), format!("{c:?}"), "seed must matter");
    }

    #[test]
    fn compile_respects_horizon_and_floor() {
        let spec = MarketSpec { volatility: Some(5.0), ..active_spec() };
        let horizon = 3.0 * 3600.0;
        let sched = compile(&spec, 7, horizon);
        assert_eq!(sched.prices.len(), (horizon / TICK_SECS).ceil() as usize);
        for &p in &sched.prices {
            assert!(p.is_finite() && p >= PRICE_FLOOR, "price {p}");
        }
        for c in &sched.crossings {
            assert!(c.at >= 0.0 && c.at < horizon, "crossing at {}", c.at);
        }
    }

    #[test]
    fn crossings_alternate_and_match_path() {
        let sched = compile(&active_spec(), 99, 86_400.0);
        assert!(!sched.crossings.is_empty(), "a volatile day should cross the bid");
        for w in sched.crossings.windows(2) {
            assert!(w[0].at < w[1].at, "crossings must be ascending");
            assert_ne!(w[0].up, w[1].up, "crossing directions must alternate");
        }
        for c in &sched.crossings {
            let k = (c.at / sched.tick).round() as usize;
            assert_eq!(sched.prices[k] > sched.bid, c.up);
            if k > 0 {
                assert_eq!(sched.prices[k - 1] > sched.bid, !c.up);
            }
        }
    }

    #[test]
    fn zero_volatility_path_follows_the_daily_mean() {
        let spec = MarketSpec {
            volatility: Some(0.0),
            mean_reversion: Some(1e-3),
            daily_amplitude: Some(0.5),
            bid_margin: Some(0.5),
        };
        let sched = compile(&spec, 5, 86_400.0);
        for (k, &p) in sched.prices.iter().enumerate() {
            let t = k as f64 * TICK_SECS;
            let mu = SPOT_MEAN_RATIO
                * ON_DEMAND_PRICE
                * (1.0 + 0.5 * (std::f64::consts::TAU * t / DAY_SECS).sin());
            assert!((p - mu.max(PRICE_FLOOR)).abs() < 1e-9, "tick {k}: {p} vs {mu}");
        }
        // amp 0.5: the mean peaks at 0.6 > bid 0.5 -> deterministic crossings.
        assert_eq!(sched.crossings.len(), 2);
        assert!(sched.crossings[0].up && !sched.crossings[1].up);
    }

    #[test]
    fn cost_integration_is_piecewise_exact() {
        let sched = MarketSchedule {
            tick: 60.0,
            od_price: 1.0,
            bid: 0.5,
            prices: vec![0.25, 0.5, 1.0],
            crossings: Vec::new(),
        };
        assert_eq!(sched.price_at(0.0), 0.25);
        assert_eq!(sched.price_at(65.0), 0.5);
        assert_eq!(sched.price_at(1e9), 1.0, "last price extends forever");
        // 30 s @ .25 + 60 s @ .5 + 30 s @ 1.0
        let c = sched.cost_over(30.0, 150.0);
        assert!((c - (30.0 * 0.25 + 60.0 * 0.5 + 30.0 * 1.0)).abs() < 1e-9, "{c}");
        // Beyond the path: the last tick price carries.
        let tail = sched.cost_over(180.0, 240.0);
        assert!((tail - 60.0).abs() < 1e-9, "{tail}");
        assert_eq!(sched.cost_over(10.0, 10.0), 0.0);
        assert_eq!(sched.max_price_over(0.0, 70.0), 0.5);
        assert_eq!(sched.max_price_over(0.0, 60.0), 0.25);
        assert_eq!(sched.max_price_over(150.0, 1e9), 1.0);
    }

    /// Engine-level: an up-crossing out-bids a running spot VM and the
    /// report carries price-derived cost stats.
    #[test]
    fn up_crossing_reclaims_spot_vm() {
        let mut cfg = EngineConfig::default();
        cfg.min_dt = 0.1;
        cfg.vm_destruction_delay = 0.0;
        let mut e = Engine::new(cfg, Box::new(FirstFit::new()));
        let dc = e.add_datacenter("dc0", 1.0);
        e.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 10_000.0, 1_000_000.0));
        let spot_cfg = SpotConfig::terminate().with_min_running(0.0).with_warning(1.0);
        let spot = e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 4), spot_cfg));
        e.submit_cloudlet(Cloudlet::new(0, 1_000_000.0, 4).with_vm(spot));
        // Hand-built schedule: price jumps above the bid at t=120.
        let sched = Arc::new(MarketSchedule {
            tick: 60.0,
            od_price: 1.0,
            bid: 0.5,
            prices: vec![0.3, 0.3, 0.7, 0.7],
            crossings: vec![Crossing { at: 120.0, up: true }],
        });
        apply(&mut e, &sched);
        e.terminate_at(600.0);
        let report = e.run();
        assert_eq!(e.world.vms[spot].state, VmState::Terminated);
        let stopped = e.world.vms[spot].stopped_at.unwrap();
        assert!((stopped - 121.0).abs() < 0.5, "warned at 120 + 1 s warning: {stopped}");
        assert_eq!(report.market.price_reclaims, 1);
        assert_eq!(report.spot.interruptions, 1);
        // Ran [0, 121) on 4 PEs at 0.3 then 0.7 $/PE-hour.
        assert!(report.market.spot_cost_usd > 0.0);
        assert!(report.market.on_demand_cost_usd > report.market.spot_cost_usd);
        assert!(report.market.savings_ratio > 0.0 && report.market.savings_ratio < 1.0);
        assert!((report.market.max_price_paid - 0.7).abs() < 1e-9);
        assert!(report.market.mean_price_paid > 0.3 && report.market.mean_price_paid < 0.7);
    }

    /// Engine-level: a down-crossing drains the retry queue so a
    /// hibernated spot resumes once the price dips back under its bid.
    #[test]
    fn down_crossing_resumes_hibernated_spot() {
        let mut cfg = EngineConfig::default();
        cfg.min_dt = 0.1;
        cfg.vm_destruction_delay = 0.0;
        cfg.resubmit_cooldown = 1.0;
        cfg.retry_interval = 1e6; // only the market event can wake it up
        let mut e = Engine::new(cfg, Box::new(FirstFit::new()));
        let dc = e.add_datacenter("dc0", 1.0);
        e.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 10_000.0, 1_000_000.0));
        let spot_cfg = SpotConfig::hibernate()
            .with_min_running(0.0)
            .with_warning(0.0)
            .with_hibernation_timeout(10_000.0);
        let spot =
            e.submit_vm(Vm::spot(0, VmSpec::new(1000.0, 8), spot_cfg).with_persistent(1_000.0));
        // 80_000 MI at 8000 MIPS -> 10 s of work once resumed.
        e.submit_cloudlet(Cloudlet::new(0, 80_000.0, 8).with_vm(spot));
        let sched = Arc::new(MarketSchedule {
            tick: 60.0,
            od_price: 1.0,
            bid: 0.5,
            prices: vec![0.3, 0.7, 0.7, 0.3, 0.3],
            crossings: vec![
                Crossing { at: 60.0, up: true },
                Crossing { at: 180.0, up: false },
            ],
        });
        apply(&mut e, &sched);
        e.terminate_at(600.0);
        let report = e.run();
        assert_eq!(e.world.vms[spot].state, VmState::Finished, "resumed and finished");
        assert_eq!(report.market.price_reclaims, 1);
        assert_eq!(report.spot.redeployments, 1);
        // Interrupted at 60, resumed at the 180 s down-crossing.
        let ivs = e.world.vms[spot].history.intervals();
        assert_eq!(ivs.len(), 2);
        assert!((ivs[1].start - 180.0).abs() < 2.0, "resumed at {}", ivs[1].start);
    }

    /// Market-free engines report all-zero market stats.
    #[test]
    fn market_free_run_reports_zero_stats() {
        let mut cfg = EngineConfig::default();
        cfg.min_dt = 0.1;
        cfg.vm_destruction_delay = 0.0;
        let mut e = Engine::new(cfg, Box::new(FirstFit::new()));
        let dc = e.add_datacenter("dc0", 1.0);
        e.add_host(dc, HostSpec::new(8, 1000.0, 16_384.0, 10_000.0, 1_000_000.0));
        let vm = e.submit_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
        e.submit_cloudlet(Cloudlet::new(0, 20_000.0, 2).with_vm(vm));
        let report = e.run();
        assert_eq!(report.market.price_reclaims, 0);
        assert_eq!(report.market.spot_cost_usd, 0.0);
        assert_eq!(report.market.savings_ratio, 0.0);
    }
}
