//! [`HostScorer`] backed by the PJRT-compiled hlem_score artifact.
//!
//! Pads host batches to the artifact's `MAX_HOSTS` and masks the padding.
//! Batches larger than the artifact shape fall back to the pure-rust
//! scorer *for the whole batch* - chunking would change the semantics
//! (Eq. 3's min-max and Eq. 4's proportions are batch-global), so partial
//! PJRT scoring would silently disagree with the oracle. The fallback is
//! counted for observability.

use std::rc::Rc;

use crate::allocation::scorer::{HostScorer, RustScorer, ScoreInput};

use super::PjrtEngine;

/// PJRT-backed scorer (shares one engine across policies via `Rc`).
pub struct PjrtScorer {
    engine: Rc<PjrtEngine>,
    fallback: RustScorer,
    /// Calls answered by the artifact.
    pub pjrt_calls: u64,
    /// Calls answered by the rust fallback (batch > MAX_HOSTS).
    pub fallback_calls: u64,
    // reusable buffers
    caps: Vec<f32>,
    free: Vec<f32>,
    spot: Vec<f32>,
    mask: Vec<f32>,
}

impl PjrtScorer {
    pub fn new(engine: Rc<PjrtEngine>) -> Self {
        let hd = engine.manifest.max_hosts * engine.manifest.dims;
        let h = engine.manifest.max_hosts;
        PjrtScorer {
            engine,
            fallback: RustScorer::new(),
            pjrt_calls: 0,
            fallback_calls: 0,
            caps: vec![0.0; hd],
            free: vec![0.0; hd],
            spot: vec![0.0; hd],
            mask: vec![0.0; h],
        }
    }

    pub fn max_hosts(&self) -> usize {
        self.engine.manifest.max_hosts
    }
}

impl HostScorer for PjrtScorer {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn scores(&mut self, input: &ScoreInput) -> (Vec<f64>, Vec<f64>) {
        input.validate();
        let n = input.len();
        let h = self.engine.manifest.max_hosts;
        let d = self.engine.manifest.dims;
        if n > h {
            self.fallback_calls += 1;
            return self.fallback.scores(input);
        }
        self.pjrt_calls += 1;

        self.caps.iter_mut().for_each(|x| *x = 0.0);
        self.free.iter_mut().for_each(|x| *x = 0.0);
        self.spot.iter_mut().for_each(|x| *x = 0.0);
        self.mask.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..n {
            for k in 0..d {
                self.caps[i * d + k] = input.caps[i][k] as f32;
                self.free[i * d + k] = input.free[i][k] as f32;
                self.spot[i * d + k] = input.spot_used[i][k] as f32;
            }
            self.mask[i] = if input.mask[i] { 1.0 } else { 0.0 };
        }

        let (hs, ahs) = self
            .engine
            .hlem_scores_f32(&self.caps, &self.free, &self.spot, &self.mask, input.alpha as f32)
            .expect("PJRT hlem_score execution failed");
        (
            hs[..n].iter().map(|&x| x as f64).collect(),
            ahs[..n].iter().map(|&x| x as f64).collect(),
        )
    }
}
