//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! protos with 64-bit instruction ids which xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md and
//! DESIGN.md §5). Each artifact is compiled once at load time; executions
//! are synchronous (the DES is single-threaded by design).

pub mod artifacts;
pub mod pjrt_scorer;
pub mod pjrt_step;

pub use artifacts::{default_artifacts_dir, ArtifactManifest};
pub use pjrt_scorer::PjrtScorer;
pub use pjrt_step::{PjrtBackend, PjrtStep};

use anyhow::{Context, Result};

/// A PJRT CPU client plus the compiled executables for both artifacts.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    hlem: xla::PjRtLoadedExecutable,
    step: xla::PjRtLoadedExecutable,
    pub manifest: ArtifactManifest,
}

impl PjrtEngine {
    /// Load and compile both artifacts from `dir` (usually `artifacts/`).
    pub fn load(dir: &std::path::Path) -> Result<Self> {
        let manifest = ArtifactManifest::load(dir)
            .with_context(|| format!("loading MANIFEST.json from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;

        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
        };

        let hlem = compile(&manifest.hlem_file)?;
        let step = compile(&manifest.step_file)?;
        Ok(PjrtEngine { client, hlem, step, manifest })
    }

    /// Convenience: load from the default `artifacts/` directory.
    pub fn load_default() -> Result<Self> {
        Self::load(&default_artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute the hlem_score artifact on padded f32 buffers.
    ///
    /// All matrices are row-major `[max_hosts][dims]` flattened; returns
    /// `(hs, ahs)` of length `max_hosts`.
    pub fn hlem_scores_f32(
        &self,
        caps: &[f32],
        free: &[f32],
        spot_used: &[f32],
        mask: &[f32],
        alpha: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let h = self.manifest.max_hosts;
        let d = self.manifest.dims;
        assert_eq!(caps.len(), h * d, "caps must be padded to [{h},{d}]");
        assert_eq!(free.len(), h * d);
        assert_eq!(spot_used.len(), h * d);
        assert_eq!(mask.len(), h);

        let mat = |data: &[f32]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(data).reshape(&[h as i64, d as i64])?)
        };
        let args = [
            mat(caps)?,
            mat(free)?,
            mat(spot_used)?,
            xla::Literal::vec1(mask),
            xla::Literal::scalar(alpha),
        ];
        let result = self.hlem.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 2, "hlem artifact returned {} outputs", outs.len());
        let mut it = outs.into_iter();
        let hs = it.next().unwrap().to_vec::<f32>()?;
        let ahs = it.next().unwrap().to_vec::<f32>()?;
        Ok((hs, ahs))
    }

    /// Execute the cloudlet_step artifact on padded f32 buffers; returns
    /// `(remaining', finished)` of length `max_cloudlets`.
    pub fn cloudlet_step_f32(
        &self,
        remaining: &[f32],
        mips: &[f32],
        dt: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let n = self.manifest.max_cloudlets;
        assert_eq!(remaining.len(), n, "remaining must be padded to [{n}]");
        assert_eq!(mips.len(), n);
        let args = [
            xla::Literal::vec1(remaining),
            xla::Literal::vec1(mips),
            xla::Literal::scalar(dt),
        ];
        let result = self.step.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        anyhow::ensure!(outs.len() == 2, "step artifact returned {} outputs", outs.len());
        let mut it = outs.into_iter();
        let rem = it.next().unwrap().to_vec::<f32>()?;
        let fin = it.next().unwrap().to_vec::<f32>()?;
        Ok((rem, fin))
    }
}
