//! Cloudlet-progress backend executing the AOT `cloudlet_step` artifact
//! (the L1 pallas kernel) through PJRT.
//!
//! Batches larger than the artifact's `MAX_CLOUDLETS` are processed in
//! chunks - unlike host scoring, the progress update is elementwise, so
//! chunking is semantics-preserving.

use std::rc::Rc;

use crate::engine::progress::ProgressBackend;

use super::PjrtEngine;

/// Thin handle around the compiled step executable with reusable buffers.
pub struct PjrtStep {
    engine: Rc<PjrtEngine>,
    rem_buf: Vec<f32>,
    mips_buf: Vec<f32>,
    pub calls: u64,
}

impl PjrtStep {
    pub fn new(engine: Rc<PjrtEngine>) -> Self {
        let n = engine.manifest.max_cloudlets;
        PjrtStep { engine, rem_buf: vec![0.0; n], mips_buf: vec![0.0; n], calls: 0 }
    }

    pub fn batch_size(&self) -> usize {
        self.engine.manifest.max_cloudlets
    }

    /// One chunk (<= max_cloudlets) through the artifact.
    fn step_chunk(
        &mut self,
        remaining: &mut [f64],
        mips: &[f64],
        dt: f64,
        base: usize,
        finished: &mut Vec<usize>,
    ) {
        let n = self.engine.manifest.max_cloudlets;
        debug_assert!(remaining.len() <= n);
        self.rem_buf.iter_mut().for_each(|x| *x = 0.0);
        self.mips_buf.iter_mut().for_each(|x| *x = 0.0);
        for (i, (&r, &m)) in remaining.iter().zip(mips.iter()).enumerate() {
            self.rem_buf[i] = r as f32;
            self.mips_buf[i] = m as f32;
        }
        let (rem, fin) = self
            .engine
            .cloudlet_step_f32(&self.rem_buf, &self.mips_buf, dt as f32)
            .expect("PJRT cloudlet_step execution failed");
        self.calls += 1;
        for i in 0..remaining.len() {
            remaining[i] = rem[i] as f64;
            if fin[i] > 0.5 {
                finished.push(base + i);
            }
        }
    }
}

/// [`ProgressBackend`] adapter.
pub struct PjrtBackend(pub PjrtStep);

impl ProgressBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn step(&mut self, remaining: &mut [f64], mips: &[f64], dt: f64, finished: &mut Vec<usize>) {
        let chunk = self.0.batch_size();
        let mut base = 0;
        let n = remaining.len();
        while base < n {
            let end = (base + chunk).min(n);
            let (rem_chunk, mips_chunk) = (&mut remaining[base..end], &mips[base..end]);
            self.0.step_chunk(rem_chunk, mips_chunk, dt, base, finished);
            base = end;
        }
    }
}
