//! Artifact manifest: shapes + provenance written by `python/compile/aot.py`
//! (`artifacts/MANIFEST.json`), validated on load so a stale or mismatched
//! artifact fails loudly instead of mis-scoring.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json;

/// Parsed MANIFEST.json.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub source_hash: String,
    pub jax_version: String,
    pub hlem_file: String,
    pub max_hosts: usize,
    pub dims: usize,
    pub step_file: String,
    pub max_cloudlets: usize,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("MANIFEST.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow::anyhow!("parsing MANIFEST.json: {e}"))?;

        let get_str = |keys: &[&str]| -> Result<String> {
            Ok(v.path(keys)
                .and_then(|x| x.as_str())
                .with_context(|| format!("MANIFEST missing {keys:?}"))?
                .to_string())
        };
        let get_num = |keys: &[&str]| -> Result<usize> {
            Ok(v.path(keys)
                .and_then(|x| x.as_f64())
                .with_context(|| format!("MANIFEST missing {keys:?}"))? as usize)
        };

        let m = ArtifactManifest {
            source_hash: get_str(&["source_hash"])?,
            jax_version: get_str(&["jax_version"])?,
            hlem_file: get_str(&["entry_points", "hlem_score", "file"])?,
            max_hosts: get_num(&["entry_points", "hlem_score", "max_hosts"])?,
            dims: get_num(&["entry_points", "hlem_score", "dims"])?,
            step_file: get_str(&["entry_points", "cloudlet_step", "file"])?,
            max_cloudlets: get_num(&["entry_points", "cloudlet_step", "max_cloudlets"])?,
        };
        anyhow::ensure!(m.dims == 4, "artifact dims {} != engine DIMS 4", m.dims);
        anyhow::ensure!(m.max_hosts > 0 && m.max_cloudlets > 0, "degenerate artifact shapes");
        Ok(m)
    }
}

/// `artifacts/` next to the workspace root (env `CLOUDMARKET_ARTIFACTS`
/// overrides; useful for tests and packaged installs).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("CLOUDMARKET_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // CARGO_MANIFEST_DIR points at the workspace root for this crate.
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when the AOT artifacts are present (tests gate on this so the
/// pure-rust suite still runs before `make artifacts`).
pub fn artifacts_available() -> bool {
    let dir = default_artifacts_dir();
    dir.join("MANIFEST.json").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_when_artifacts_built() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = ArtifactManifest::load(&default_artifacts_dir()).unwrap();
        assert_eq!(m.dims, 4);
        assert!(m.max_hosts >= 1);
        assert!(m.max_cloudlets >= 1);
        assert!(default_artifacts_dir().join(&m.hlem_file).exists());
        assert!(default_artifacts_dir().join(&m.step_file).exists());
    }
}
