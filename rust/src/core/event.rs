//! Simulation events (paper §V-A(c): "SimEvent ... contains a type
//! identifier, timestamp, source and destination entities, and an optional
//! payload").

/// Identifies a simulation entity, mirroring CloudSim Plus's `SimEntity`
/// roles. Dispatch is central (the engine), but source/destination are kept
//  on events for observability and log fidelity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EntityId {
    /// The simulation kernel itself (clock ticks, termination).
    Kernel,
    /// A datacenter broker (user-side agent), by index.
    Broker(usize),
    /// A datacenter, by index.
    Datacenter(usize),
}

/// An event scheduled on the future queue.
#[derive(Debug, Clone)]
pub struct SimEvent<T> {
    /// Absolute simulation time at which the event fires.
    pub time: f64,
    /// FIFO tiebreaker assigned by the queue at scheduling time.
    pub seq: u64,
    pub src: EntityId,
    pub dst: EntityId,
    /// Event type + payload (the engine's `Tag`).
    pub data: T,
}

impl<T> SimEvent<T> {
    pub fn new(time: f64, src: EntityId, dst: EntityId, data: T) -> Self {
        SimEvent { time, seq: 0, src, dst, data }
    }
}
