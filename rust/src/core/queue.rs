//! Future event queue: a binary min-heap on (time, seq).
//!
//! CloudSim Plus keeps a timestamp-sorted *future* queue and moves due
//! events to a *deferred* queue for processing (paper Fig. 1 / §V-A(a)).
//! A single heap with FIFO tiebreak gives identical processing order with
//! one less copy; `pop_due` exposes the deferred-queue batch semantics
//! where the engine needs them (all events at the same timestamp).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::event::SimEvent;

struct HeapEntry<T> {
    time: f64,
    seq: u64,
    ev: SimEvent<T>,
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for HeapEntry<T> {}

impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first and
        // FIFO among equal timestamps.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Future event queue.
pub struct EventQueue<T> {
    heap: BinaryHeap<HeapEntry<T>>,
    next_seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule an event; assigns the FIFO sequence number. Panics on a
    /// non-finite or NaN timestamp (always a simulation bug).
    pub fn push(&mut self, mut ev: SimEvent<T>) {
        assert!(ev.time.is_finite(), "event scheduled at non-finite time");
        ev.seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(HeapEntry { time: ev.time, seq: ev.seq, ev });
    }

    /// Timestamp of the earliest pending event.
    pub fn next_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<SimEvent<T>> {
        self.heap.pop().map(|e| e.ev)
    }

    /// Append every event with `time <= t` (the deferred-queue batch) to
    /// `out`, in (time, seq) order. Allocation-free when `out` has
    /// capacity - the engine loop reuses one buffer across all ticks.
    /// `out` is *not* cleared (appends after existing contents).
    pub fn pop_due_into(&mut self, t: f64, out: &mut Vec<SimEvent<T>>) {
        while matches!(self.heap.peek(), Some(e) if e.time <= t) {
            out.push(self.heap.pop().unwrap().ev);
        }
    }

    /// Pop every event with `time <= t` (the deferred-queue batch),
    /// in (time, seq) order. Thin allocating wrapper around
    /// [`Self::pop_due_into`].
    pub fn pop_due(&mut self, t: f64) -> Vec<SimEvent<T>> {
        let mut out = Vec::new();
        self.pop_due_into(t, &mut out);
        out
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::event::EntityId;

    fn ev(t: f64, data: u32) -> SimEvent<u32> {
        SimEvent::new(t, EntityId::Kernel, EntityId::Kernel, data)
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        for (t, d) in [(5.0, 1), (1.0, 2), (3.0, 3)] {
            q.push(ev(t, d));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|e| e.data).collect();
        assert_eq!(order, vec![2, 3, 1]);
    }

    #[test]
    fn fifo_among_equal_timestamps() {
        let mut q = EventQueue::new();
        for d in 0..10 {
            q.push(ev(2.0, d));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|e| e.data).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn pop_due_batches() {
        let mut q = EventQueue::new();
        for (t, d) in [(1.0, 1), (2.0, 2), (2.0, 3), (5.0, 4)] {
            q.push(ev(t, d));
        }
        let due: Vec<u32> = q.pop_due(2.0).into_iter().map(|e| e.data).collect();
        assert_eq!(due, vec![1, 2, 3]);
        assert_eq!(q.len(), 1);
        assert_eq!(q.next_time(), Some(5.0));
    }

    #[test]
    fn pop_due_into_reuses_buffer_and_appends() {
        let mut q = EventQueue::new();
        for (t, d) in [(1.0, 1), (2.0, 2), (2.0, 3), (5.0, 4)] {
            q.push(ev(t, d));
        }
        let mut buf: Vec<SimEvent<u32>> = Vec::with_capacity(8);
        q.pop_due_into(1.0, &mut buf);
        assert_eq!(buf.iter().map(|e| e.data).collect::<Vec<_>>(), vec![1]);
        // Appends after existing contents, preserving (time, seq) order.
        q.pop_due_into(2.0, &mut buf);
        assert_eq!(buf.iter().map(|e| e.data).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(q.len(), 1);
        // Reuse without reallocation.
        let cap = buf.capacity();
        buf.clear();
        q.pop_due_into(10.0, &mut buf);
        assert_eq!(buf.iter().map(|e| e.data).collect::<Vec<_>>(), vec![4]);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push(ev(f64::NAN, 0));
    }
}
