//! Future event queue: a slab-backed event store driven by an index
//! min-heap on (time, seq).
//!
//! CloudSim Plus keeps a timestamp-sorted *future* queue and moves due
//! events to a *deferred* queue for processing (paper Fig. 1 / §V-A(a)).
//! A single priority queue with FIFO tiebreak gives identical processing
//! order with one less copy; `pop_due` exposes the deferred-queue batch
//! semantics where the engine needs them (all events at the same
//! timestamp).
//!
//! # Storage layout (§Perf: kernel hot path)
//!
//! Events are stored **once** in a slab (`Vec<Option<SimEvent<T>>>` with a
//! free list); the heap orders 24-byte `(time, seq, slot)` keys. Heap
//! sift operations therefore move fixed-size keys instead of whole event
//! payloads (`SimEvent<Tag>` is several times larger), and a popped slot
//! is recycled by the next push, so a steady-state simulation stops
//! growing the slab after its high-water mark. [`HeapEventQueue`] retains
//! the pre-slab `BinaryHeap`-of-payloads implementation as the `_scan`
//! -style oracle: `tests/properties.rs` pins the two to the same
//! (time, seq) pop order over randomized op sequences, and
//! `benches/perf_engine.rs` times slab vs. oracle.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::event::SimEvent;

/// Heap key: everything the ordering needs, payload left in the slab.
#[derive(Debug, Clone, Copy)]
struct HeapKey {
    time: f64,
    seq: u64,
    slot: u32,
}

/// Strict "fires before" on (time, seq). Times are asserted finite at
/// scheduling time, so `<` is a total order here.
#[inline]
fn before(a: &HeapKey, b: &HeapKey) -> bool {
    a.time < b.time || (a.time == b.time && a.seq < b.seq)
}

/// Future event queue (slab store + index min-heap).
pub struct EventQueue<T> {
    /// Event storage; `None` marks a free slot awaiting reuse.
    slab: Vec<Option<SimEvent<T>>>,
    /// Free slot indices (LIFO: reuse the hottest slot first).
    free: Vec<u32>,
    /// Min-heap of keys into `slab`, ordered by `before` (time, seq).
    heap: Vec<HeapKey>,
    next_seq: u64,
    /// Deepest the pending-event heap has ever been since the last
    /// [`Self::reset`] (telemetry counter; one branch per push).
    high_water: usize,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { slab: Vec::new(), free: Vec::new(), heap: Vec::new(), next_seq: 0, high_water: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Slab high-water mark (diagnostics: slots allocated, free or live).
    pub fn slab_len(&self) -> usize {
        self.slab.len()
    }

    /// Queue-depth high-water mark: the most events that were ever pending
    /// at once since the last [`Self::reset`]. Deterministic (depends only
    /// on the event stream), surfaced through the telemetry sidecar.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Schedule an event; assigns the FIFO sequence number. Panics on a
    /// non-finite or NaN timestamp (always a simulation bug).
    pub fn push(&mut self, mut ev: SimEvent<T>) {
        assert!(ev.time.is_finite(), "event scheduled at non-finite time");
        ev.seq = self.next_seq;
        self.next_seq += 1;
        let key = HeapKey { time: ev.time, seq: ev.seq, slot: 0 };
        let slot = match self.free.pop() {
            Some(s) => {
                debug_assert!(self.slab[s as usize].is_none(), "free slot occupied");
                self.slab[s as usize] = Some(ev);
                s
            }
            None => {
                let s = self.slab.len();
                assert!(s < u32::MAX as usize, "event slab overflow");
                self.slab.push(Some(ev));
                s as u32
            }
        };
        self.heap.push(HeapKey { slot, ..key });
        if self.heap.len() > self.high_water {
            self.high_water = self.heap.len();
        }
        self.sift_up(self.heap.len() - 1);
    }

    /// Timestamp of the earliest pending event.
    pub fn next_time(&self) -> Option<f64> {
        self.heap.first().map(|k| k.time)
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<SimEvent<T>> {
        if self.heap.is_empty() {
            return None;
        }
        let key = self.heap.swap_remove(0);
        if !self.heap.is_empty() {
            self.sift_down(0);
        }
        let ev = self.slab[key.slot as usize].take().expect("event slab slot empty (queue bug)");
        self.free.push(key.slot);
        Some(ev)
    }

    /// Append every event with `time <= t` (the deferred-queue batch) to
    /// `out`, in (time, seq) order. Allocation-free when `out` has
    /// capacity - the engine loop reuses one buffer across all ticks.
    /// `out` is *not* cleared (appends after existing contents).
    pub fn pop_due_into(&mut self, t: f64, out: &mut Vec<SimEvent<T>>) {
        while matches!(self.heap.first(), Some(k) if k.time <= t) {
            out.push(self.pop().expect("non-empty heap must pop"));
        }
    }

    /// Pop every event with `time <= t` (the deferred-queue batch),
    /// in (time, seq) order. Thin allocating wrapper around
    /// [`Self::pop_due_into`].
    pub fn pop_due(&mut self, t: f64) -> Vec<SimEvent<T>> {
        let mut out = Vec::new();
        self.pop_due_into(t, &mut out);
        out
    }

    /// Drop all pending events (sequence numbering continues; buffers keep
    /// their capacity).
    pub fn clear(&mut self) {
        self.heap.clear();
        self.slab.clear();
        self.free.clear();
    }

    /// [`Self::clear`] plus a sequence restart: a recycled queue behaves
    /// exactly like a fresh one while keeping its slab/heap allocations
    /// (sweep workers reuse one queue across consecutive cells). The
    /// high-water mark restarts too, so recycled queues report per-cell
    /// peaks rather than a sweep-wide maximum.
    pub fn reset(&mut self) {
        self.clear();
        self.next_seq = 0;
        self.high_water = 0;
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if before(&self.heap[i], &self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let left = 2 * i + 1;
            if left >= n {
                break;
            }
            let right = left + 1;
            let mut smallest = left;
            if right < n && before(&self.heap[right], &self.heap[left]) {
                smallest = right;
            }
            if before(&self.heap[smallest], &self.heap[i]) {
                self.heap.swap(i, smallest);
                i = smallest;
            } else {
                break;
            }
        }
    }
}

// ---------------------------------------------------------------------
// oracle
// ---------------------------------------------------------------------

struct HeapEntry<T> {
    time: f64,
    seq: u64,
    ev: SimEvent<T>,
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<T> Eq for HeapEntry<T> {}

impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first and
        // FIFO among equal timestamps.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The pre-slab future event queue: a `BinaryHeap` carrying whole event
/// payloads. Kept as the ordering oracle for [`EventQueue`] (the PR-1
/// `_scan` pattern): same API, same (time, seq) pop order, used by the
/// randomized property test and as the bench baseline. Not used on the
/// production hot path.
pub struct HeapEventQueue<T> {
    heap: BinaryHeap<HeapEntry<T>>,
    next_seq: u64,
}

impl<T> Default for HeapEventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> HeapEventQueue<T> {
    pub fn new() -> Self {
        HeapEventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule an event (same contract as [`EventQueue::push`]).
    pub fn push(&mut self, mut ev: SimEvent<T>) {
        assert!(ev.time.is_finite(), "event scheduled at non-finite time");
        ev.seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(HeapEntry { time: ev.time, seq: ev.seq, ev });
    }

    pub fn next_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn pop(&mut self) -> Option<SimEvent<T>> {
        self.heap.pop().map(|e| e.ev)
    }

    /// Same batch semantics as [`EventQueue::pop_due_into`].
    pub fn pop_due_into(&mut self, t: f64, out: &mut Vec<SimEvent<T>>) {
        while matches!(self.heap.peek(), Some(e) if e.time <= t) {
            out.push(self.heap.pop().expect("non-empty heap must pop").ev);
        }
    }

    pub fn pop_due(&mut self, t: f64) -> Vec<SimEvent<T>> {
        let mut out = Vec::new();
        self.pop_due_into(t, &mut out);
        out
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::event::EntityId;

    fn ev(t: f64, data: u32) -> SimEvent<u32> {
        SimEvent::new(t, EntityId::Kernel, EntityId::Kernel, data)
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        for (t, d) in [(5.0, 1), (1.0, 2), (3.0, 3)] {
            q.push(ev(t, d));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|e| e.data).collect();
        assert_eq!(order, vec![2, 3, 1]);
    }

    #[test]
    fn fifo_among_equal_timestamps() {
        let mut q = EventQueue::new();
        for d in 0..10 {
            q.push(ev(2.0, d));
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop()).map(|e| e.data).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn pop_due_batches() {
        let mut q = EventQueue::new();
        for (t, d) in [(1.0, 1), (2.0, 2), (2.0, 3), (5.0, 4)] {
            q.push(ev(t, d));
        }
        let due: Vec<u32> = q.pop_due(2.0).into_iter().map(|e| e.data).collect();
        assert_eq!(due, vec![1, 2, 3]);
        assert_eq!(q.len(), 1);
        assert_eq!(q.next_time(), Some(5.0));
    }

    #[test]
    fn pop_due_into_reuses_buffer_and_appends() {
        let mut q = EventQueue::new();
        for (t, d) in [(1.0, 1), (2.0, 2), (2.0, 3), (5.0, 4)] {
            q.push(ev(t, d));
        }
        let mut buf: Vec<SimEvent<u32>> = Vec::with_capacity(8);
        q.pop_due_into(1.0, &mut buf);
        assert_eq!(buf.iter().map(|e| e.data).collect::<Vec<_>>(), vec![1]);
        // Appends after existing contents, preserving (time, seq) order.
        q.pop_due_into(2.0, &mut buf);
        assert_eq!(buf.iter().map(|e| e.data).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(q.len(), 1);
        // Reuse without reallocation.
        let cap = buf.capacity();
        buf.clear();
        q.pop_due_into(10.0, &mut buf);
        assert_eq!(buf.iter().map(|e| e.data).collect::<Vec<_>>(), vec![4]);
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push(ev(f64::NAN, 0));
    }

    /// Steady-state push/pop cycles recycle slab slots instead of growing
    /// the store.
    #[test]
    fn slab_slots_are_recycled() {
        let mut q = EventQueue::new();
        for round in 0..100 {
            for d in 0..4 {
                q.push(ev(round as f64 + d as f64 * 0.1, d));
            }
            while q.pop().is_some() {}
        }
        assert!(q.slab_len() <= 4, "slab grew past its high-water mark: {}", q.slab_len());
    }

    /// The depth high-water mark tracks the peak, survives `clear`, and
    /// restarts on `reset` (per-cell peaks for recycled queues).
    #[test]
    fn high_water_tracks_peak_depth_until_reset() {
        let mut q = EventQueue::new();
        assert_eq!(q.high_water(), 0);
        for d in 0..5 {
            q.push(ev(d as f64, d));
        }
        q.pop();
        q.pop();
        q.push(ev(9.0, 9));
        assert_eq!(q.high_water(), 5, "peak was 5 pending events");
        q.clear();
        assert_eq!(q.high_water(), 5, "clear keeps the mark");
        q.reset();
        assert_eq!(q.high_water(), 0, "reset restarts the mark");
        q.push(ev(1.0, 0));
        assert_eq!(q.high_water(), 1);
    }

    /// `reset` restarts sequence numbering; `clear` does not.
    #[test]
    fn reset_restarts_sequences() {
        let mut q = EventQueue::new();
        q.push(ev(1.0, 0));
        q.clear();
        q.push(ev(1.0, 1));
        assert_eq!(q.pop().unwrap().seq, 1);
        q.reset();
        q.push(ev(1.0, 2));
        assert_eq!(q.pop().unwrap().seq, 0);
    }

    /// Smoke parity with the retained `BinaryHeap` oracle (the full
    /// randomized pinning lives in `tests/properties.rs`).
    #[test]
    fn matches_heap_oracle_on_interleaved_ops() {
        let mut q = EventQueue::new();
        let mut oracle = HeapEventQueue::new();
        let times = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0, 5.0, 3.0];
        for (i, &t) in times.iter().enumerate() {
            q.push(ev(t, i as u32));
            oracle.push(ev(t, i as u32));
        }
        for _ in 0..4 {
            let (a, b) = (q.pop().unwrap(), oracle.pop().unwrap());
            assert_eq!((a.time, a.seq, a.data), (b.time, b.seq, b.data));
        }
        let (a, b) = (q.pop_due(5.0), oracle.pop_due(5.0));
        assert_eq!(
            a.iter().map(|e| (e.seq, e.data)).collect::<Vec<_>>(),
            b.iter().map(|e| (e.seq, e.data)).collect::<Vec<_>>()
        );
        assert_eq!(q.next_time(), oracle.next_time());
        assert_eq!(q.len(), oracle.len());
    }
}
