//! The simulation clock + event loop driver (the `CloudSim` class role,
//! paper §V-A(a)).

use super::event::{EntityId, SimEvent};
use super::queue::EventQueue;

/// Simulation kernel: clock, future event queue, termination condition.
///
/// The processing loop itself lives in the engine (which owns the world
/// state); `Simulation` provides the clock/queue mechanics so they can be
/// tested and reused independently.
pub struct Simulation<T> {
    clock: f64,
    queue: EventQueue<T>,
    /// Events scheduled less than this far apart are quantized up
    /// (CloudSim's "minimal time between events", Listing 2).
    min_dt: f64,
    /// Hard termination time (`terminateAt`); events beyond it are dropped
    /// at processing time.
    terminate_at: Option<f64>,
    processed: u64,
}

impl<T> Simulation<T> {
    /// `min_dt` mirrors `new CloudSim(0.5)`: a floor on how soon after the
    /// current clock a new event may fire.
    pub fn new(min_dt: f64) -> Self {
        Self::with_queue(min_dt, EventQueue::new())
    }

    /// [`Self::new`] with a recycled event queue: the queue is reset to a
    /// pristine state but keeps its slab/heap allocations, so a sweep
    /// worker running consecutive cells pays the queue's high-water
    /// allocation once instead of per cell.
    pub fn with_queue(min_dt: f64, mut queue: EventQueue<T>) -> Self {
        assert!(min_dt >= 0.0 && min_dt.is_finite());
        queue.reset();
        Simulation { clock: 0.0, queue, min_dt, terminate_at: None, processed: 0 }
    }

    /// Tear the simulation down, handing the event queue back for reuse
    /// (see [`Self::with_queue`]).
    pub fn into_queue(self) -> EventQueue<T> {
        self.queue
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn min_dt(&self) -> f64 {
        self.min_dt
    }

    pub fn processed_events(&self) -> u64 {
        self.processed
    }

    pub fn pending_events(&self) -> usize {
        self.queue.len()
    }

    /// Queue-depth high-water mark for this simulation (see
    /// [`EventQueue::high_water`]); a deterministic telemetry counter.
    pub fn queue_high_water(&self) -> usize {
        self.queue.high_water()
    }

    /// Set the hard stop time (paper: `simulation.terminateAt(70)`).
    pub fn terminate_at(&mut self, t: f64) {
        assert!(t.is_finite());
        self.terminate_at = Some(t);
    }

    pub fn termination_time(&self) -> Option<f64> {
        self.terminate_at
    }

    /// Schedule `data` to fire `delay` seconds from now.
    pub fn schedule(&mut self, delay: f64, src: EntityId, dst: EntityId, data: T) {
        assert!(delay >= 0.0, "negative delay {delay}");
        let t = self.quantize(self.clock + delay);
        self.queue.push(SimEvent::new(t, src, dst, data));
    }

    /// Schedule at an absolute time (>= clock; quantized by `min_dt`).
    pub fn schedule_at(&mut self, time: f64, src: EntityId, dst: EntityId, data: T) {
        let t = self.quantize(time.max(self.clock));
        self.queue.push(SimEvent::new(t, src, dst, data));
    }

    fn quantize(&self, t: f64) -> f64 {
        // Enforce a floor of min_dt after the current clock for any event
        // that is not immediate (t == clock is allowed: same-tick cascades).
        if t > self.clock && t < self.clock + self.min_dt {
            self.clock + self.min_dt
        } else {
            t
        }
    }

    /// Pop the next event and advance the clock to it. Returns `None` when
    /// the queue is empty or the next event lies beyond `terminate_at`
    /// (in which case the clock advances to the termination time).
    pub fn next_event(&mut self) -> Option<SimEvent<T>> {
        let t = self.queue.next_time()?;
        if let Some(end) = self.terminate_at {
            if t > end {
                self.clock = end;
                self.queue.clear();
                return None;
            }
        }
        let ev = self.queue.pop()?;
        debug_assert!(ev.time + 1e-9 >= self.clock, "time went backwards");
        self.clock = ev.time.max(self.clock);
        self.processed += 1;
        Some(ev)
    }

    /// Pop the whole batch of events sharing the next pending timestamp,
    /// appending them to `out` in (time, seq) order, and advance the
    /// clock once. Returns `false` (leaving `out` untouched) when the
    /// queue is empty or the next event lies beyond `terminate_at` (in
    /// which case the clock parks at the termination time, exactly like
    /// [`Self::next_event`]).
    ///
    /// Equivalent to calling [`Self::next_event`] until the timestamp
    /// changes, minus the per-tick `Vec` allocation: the engine loop
    /// reuses one buffer across all batches.
    pub fn next_batch_into(&mut self, out: &mut Vec<SimEvent<T>>) -> bool {
        let Some(t) = self.queue.next_time() else {
            return false;
        };
        if let Some(end) = self.terminate_at {
            if t > end {
                self.clock = end;
                self.queue.clear();
                return false;
            }
        }
        debug_assert!(t + 1e-9 >= self.clock, "time went backwards");
        self.clock = t.max(self.clock);
        let before = out.len();
        self.queue.pop_due_into(t, out);
        self.processed += (out.len() - before) as u64;
        true
    }

    /// True when no further event can fire.
    pub fn is_finished(&self) -> bool {
        match (self.queue.next_time(), self.terminate_at) {
            (None, _) => true,
            (Some(t), Some(end)) => t > end,
            (Some(_), None) => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::event::EntityId::Kernel;

    #[test]
    fn clock_advances_monotonically() {
        let mut sim: Simulation<u32> = Simulation::new(0.0);
        sim.schedule(5.0, Kernel, Kernel, 1);
        sim.schedule(2.0, Kernel, Kernel, 2);
        let e = sim.next_event().unwrap();
        assert_eq!((e.data, sim.clock()), (2, 2.0));
        let e = sim.next_event().unwrap();
        assert_eq!((e.data, sim.clock()), (1, 5.0));
        assert!(sim.is_finished());
        assert_eq!(sim.processed_events(), 2);
        assert_eq!(sim.queue_high_water(), 2, "both events were pending at once");
    }

    #[test]
    fn min_dt_quantizes_near_events() {
        let mut sim: Simulation<u32> = Simulation::new(0.5);
        sim.schedule(0.1, Kernel, Kernel, 1); // bumped to 0.5
        sim.schedule(0.0, Kernel, Kernel, 2); // immediate: allowed at t=0
        let e = sim.next_event().unwrap();
        assert_eq!((e.data, sim.clock()), (2, 0.0));
        let e = sim.next_event().unwrap();
        assert_eq!((e.data, sim.clock()), (1, 0.5));
    }

    #[test]
    fn terminate_at_drops_late_events() {
        let mut sim: Simulation<u32> = Simulation::new(0.0);
        sim.terminate_at(10.0);
        sim.schedule(5.0, Kernel, Kernel, 1);
        sim.schedule(50.0, Kernel, Kernel, 2);
        assert_eq!(sim.next_event().unwrap().data, 1);
        assert!(sim.next_event().is_none());
        assert_eq!(sim.clock(), 10.0); // clock parked at termination time
        assert!(sim.is_finished());
    }

    #[test]
    fn schedule_at_clamps_to_now() {
        let mut sim: Simulation<u32> = Simulation::new(0.0);
        sim.schedule(1.0, Kernel, Kernel, 1);
        sim.next_event().unwrap();
        sim.schedule_at(0.2, Kernel, Kernel, 2); // in the past -> now
        let e = sim.next_event().unwrap();
        assert_eq!(e.time, 1.0);
    }

    #[test]
    fn next_batch_matches_single_pop_semantics() {
        let mut sim: Simulation<u32> = Simulation::new(0.0);
        sim.terminate_at(10.0);
        for (t, d) in [(1.0, 1), (2.0, 2), (2.0, 3), (50.0, 4)] {
            sim.schedule_at(t, Kernel, Kernel, d);
        }
        let mut batch = Vec::new();
        assert!(sim.next_batch_into(&mut batch));
        assert_eq!(batch.iter().map(|e| e.data).collect::<Vec<_>>(), vec![1]);
        assert_eq!(sim.clock(), 1.0);
        batch.clear();
        assert!(sim.next_batch_into(&mut batch));
        assert_eq!(batch.iter().map(|e| e.data).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(sim.clock(), 2.0);
        assert_eq!(sim.processed_events(), 3);
        batch.clear();
        // Next event beyond terminate_at: clock parks at the stop time.
        assert!(!sim.next_batch_into(&mut batch));
        assert!(batch.is_empty());
        assert_eq!(sim.clock(), 10.0);
        assert!(sim.is_finished());
    }

    /// A recycled queue starts a new simulation from a pristine state
    /// (fresh sequence numbers, empty heap), keeping only its capacity.
    #[test]
    fn recycled_queue_behaves_like_fresh() {
        let mut sim: Simulation<u32> = Simulation::new(0.0);
        sim.schedule(1.0, Kernel, Kernel, 1);
        sim.next_event().unwrap();
        let q = sim.into_queue();
        let mut sim2: Simulation<u32> = Simulation::with_queue(0.0, q);
        assert_eq!(sim2.clock(), 0.0);
        sim2.schedule(2.0, Kernel, Kernel, 7);
        let e = sim2.next_event().unwrap();
        assert_eq!((e.data, e.seq, sim2.clock()), (7, 0, 2.0));
        assert!(sim2.is_finished());
    }

    #[test]
    #[should_panic(expected = "negative delay")]
    fn rejects_negative_delay() {
        let mut sim: Simulation<u32> = Simulation::new(0.0);
        sim.schedule(-1.0, Kernel, Kernel, 1);
    }
}
