//! Discrete-event simulation kernel (the CloudSim/CloudSim Plus execution
//! backbone re-implemented in Rust - paper §V-A).
//!
//! The kernel is deliberately generic over the event payload type `T` so it
//! can be unit- and property-tested in isolation from the cloud model; the
//! engine in [`crate::engine`] instantiates it with [`crate::engine::Tag`].
//!
//! Semantics mirrored from CloudSim Plus:
//! - a *future event queue* ordered by timestamp (ties broken FIFO by
//!   scheduling sequence, as CloudSim does via the deferred queue),
//! - a monotone simulation clock advanced to each processed event,
//! - `min_time_between_events` quantization (constructor argument of the
//!   `CloudSim` class, Listing 2 of the paper),
//! - `terminate_at` (the paper's `simulation.terminateAt(70)`).

pub mod event;
pub mod queue;
pub mod sim;

pub use event::{EntityId, SimEvent};
pub use queue::{EventQueue, HeapEventQueue};
pub use sim::Simulation;
