//! Bench + regeneration of paper Fig. 16 (spot-advisor correlation).

use cloudmarket::analysis::advisor::synth_dataset;
use cloudmarket::analysis::{correlation_ratio, pearson, theils_u};
use cloudmarket::benchkit::{banner, black_box, Bencher};
use cloudmarket::experiments::advisor;

fn main() {
    banner("FIG 16: feature vs interruption-frequency association");
    let ds = advisor::dataset(None, 7);
    println!(
        "dataset: {} rows ({} types x 3 regions x 2 OS)",
        ds.rows.len(),
        ds.type_names.len()
    );
    println!("{}", advisor::class_distribution_table(&ds).render());
    println!("{}", advisor::fig16_table(&ds).render());

    banner("timings");
    let class: Vec<u32> = ds.rows.iter().map(|r| r.interruption_class).collect();
    let types: Vec<u32> = ds.rows.iter().map(|r| r.instance_type).collect();
    let vcpus: Vec<f64> = ds.rows.iter().map(|r| r.vcpus).collect();
    let savings: Vec<f64> = ds.rows.iter().map(|r| r.savings_pct).collect();
    let classf: Vec<f64> = class.iter().map(|&c| c as f64).collect();

    let mut b = Bencher::new();
    let n = ds.rows.len() as f64;
    b.bench("synthesize dataset", Some(n), || {
        black_box(synth_dataset(7));
    });
    b.bench("theils_u(type, class)", Some(n), || {
        black_box(theils_u(&types, &class));
    });
    b.bench("correlation_ratio(class, vcpus)", Some(n), || {
        black_box(correlation_ratio(&class, &vcpus));
    });
    b.bench("pearson(savings, class)", Some(n), || {
        black_box(pearson(&savings, &classf));
    });
    b.bench("full fig16 association table", Some(n), || {
        black_box(ds.fig16_associations());
    });
    b.write_json(std::path::Path::new("results/bench_fig16.json")).ok();
}
