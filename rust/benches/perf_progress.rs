//! §Perf L3/L1: cloudlet-progress backends - the paper's measured
//! bottleneck ("performance was constrained by cloudlet execution
//! updates", §VII-D.1) ablated three ways:
//!
//! - naive: per-object scalar walk (the CloudSim-style baseline),
//! - batched: SIMD-friendly parallel-array loop (production default),
//! - pjrt: the AOT pallas kernel through the PJRT CPU client.

use std::rc::Rc;

use cloudmarket::benchkit::{banner, black_box, Bencher};
use cloudmarket::engine::progress::{BatchedBackend, NaiveBackend, ProgressBackend};
use cloudmarket::runtime::{artifacts, PjrtBackend, PjrtEngine, PjrtStep};
use cloudmarket::stats::Rng;

fn workload(rng: &mut Rng, n: usize) -> (Vec<f64>, Vec<f64>) {
    let rem: Vec<f64> = (0..n)
        .map(|_| if rng.chance(0.1) { 0.0 } else { rng.uniform(1e3, 1e7) })
        .collect();
    let mips: Vec<f64> = (0..n).map(|_| rng.uniform(100.0, 4e3)).collect();
    (rem, mips)
}

fn bench_backend(b: &mut Bencher, name: &str, backend: &mut dyn ProgressBackend, n: usize) {
    let mut rng = Rng::new(7);
    let (rem0, mips) = workload(&mut rng, n);
    let mut rem = rem0.clone();
    let mut fin = Vec::new();
    b.bench(&format!("{name} N={n}"), Some(n as f64), || {
        rem.copy_from_slice(&rem0);
        fin.clear();
        backend.step(&mut rem, &mips, 1.0, &mut fin);
        black_box(&fin);
    });
}

fn main() {
    banner("PERF: cloudlet progress backends (the paper's bottleneck)");
    let mut b = Bencher::new();
    for &n in &[1_024usize, 16_384, 262_144] {
        bench_backend(&mut b, "naive", &mut NaiveBackend, n);
        bench_backend(&mut b, "batched", &mut BatchedBackend, n);
    }
    if artifacts::artifacts_available() {
        let engine = Rc::new(PjrtEngine::load_default().expect("loading artifacts"));
        let mut pjrt = PjrtBackend(PjrtStep::new(engine));
        for &n in &[1_024usize, 16_384, 262_144] {
            bench_backend(&mut b, "pjrt", &mut pjrt, n);
        }
    } else {
        println!("(artifacts not built - run `make artifacts` for the PJRT side)");
    }
    b.write_json(std::path::Path::new("results/bench_progress.json")).ok();
}
