//! Bench + regeneration of paper Fig. 12 and the §VII-D statistics
//! (cluster-trace simulation with injected spot instances).
//!
//! Uses a reduced scale (50 machines x 0.25 day) so the bench iterates;
//! the full-scale run is `examples/cluster_trace.rs`.

use cloudmarket::benchkit::{banner, black_box, Bencher};
use cloudmarket::experiments::trace_sim::{self, TraceSimConfig};
use cloudmarket::trace::synth::SynthConfig;
use cloudmarket::trace::workload::WorkloadConfig;

fn bench_cfg() -> TraceSimConfig {
    TraceSimConfig {
        synth: SynthConfig {
            machines: 50,
            days: 0.25,
            tasks_per_hour: 500.0,
            ..Default::default()
        },
        workload: WorkloadConfig {
            spot_instances: 300,
            spot_durations: vec![1_800.0, 3_600.0],
            max_trace_vms: 3_000,
            ..Default::default()
        },
        profile: false,
        sample_interval: 120.0,
    }
}

fn main() {
    banner("FIG 12 + SVII-D: cluster-trace simulation (bench scale)");
    let cfg = bench_cfg();
    let out = trace_sim::run(&cfg);
    println!("{}", trace_sim::results_table(&out).render());
    println!("{}", out.series.ascii_chart("spot_running", 90, 10));
    let events = out.report.events_processed as f64;
    println!(
        "events/sec: {:.0}",
        events / out.report.wall.as_secs_f64()
    );

    banner("timings (full run per iteration)");
    let mut b = Bencher::heavy();
    b.bench("trace sim 50 machines x 6h", Some(events), || {
        black_box(trace_sim::run(&bench_cfg()));
    });
    b.write_json(std::path::Path::new("results/bench_fig12.json")).ok();
}
