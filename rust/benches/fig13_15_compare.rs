//! Bench + regeneration of paper Tables II-III and Figs. 13-15
//! (allocation-algorithm comparison).

use cloudmarket::benchkit::{banner, black_box, Bencher};
use cloudmarket::config::catalog;
use cloudmarket::config::scenario::ComparisonConfig;
use cloudmarket::experiments::compare;

fn main() {
    banner("TABLES II-III + FIGS 13-15: allocation-algorithm comparison");
    println!("{}", catalog::host_table().render());
    println!("{}", catalog::vm_table().render());

    let cfg = ComparisonConfig::default();
    let outcomes = compare::run_all(&cfg);
    println!("{}", compare::fig14_table(&outcomes).render());
    println!("{}", compare::fig15_table(&outcomes).render());
    println!("{}", compare::shape_summary(&outcomes));

    compare::fig13_csv(&outcomes)
        .write_file(std::path::Path::new("results/fig13_active_instances.csv"))
        .ok();

    banner("multi-seed aggregate (5 seeds)");
    let aggs = compare::run_multi(&cfg, 5);
    println!("{}", compare::aggregate_table(&aggs).render());

    banner("timings (one full policy run per iteration)");
    let mut b = Bencher::heavy();
    for (name, make) in compare::paper_policies() {
        b.bench(&format!("scenario under {name}"), Some(2_007.0), || {
            black_box(compare::run_policy(make, &cfg));
        });
    }
    b.write_json(std::path::Path::new("results/bench_fig13_15.json")).ok();
}
