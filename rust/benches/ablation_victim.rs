//! Ablation (paper §IX future work): victim-selection policy for spot
//! preemption. The paper's implementation picks victims in host VM-list
//! order and calls smarter targeting future work - here all three
//! strategies run the full comparison scenario.

use cloudmarket::allocation::{FirstFit, HlemConfig, HlemVmp};
use cloudmarket::benchkit::banner;
use cloudmarket::config::scenario::{build_comparison_workload, ComparisonConfig};
use cloudmarket::engine::{Engine, EngineConfig, VictimPolicy};
use cloudmarket::util::table::{Align, TextTable};

fn run(policy_name: &str, victim: VictimPolicy) -> (u64, f64, f64) {
    let cfg = ComparisonConfig::default();
    let mut engine_cfg = EngineConfig::default();
    engine_cfg.vm_destruction_delay = 1.0;
    let policy: Box<dyn cloudmarket::allocation::AllocationPolicy> = match policy_name {
        "first-fit" => Box::new(FirstFit::new().with_victim_policy(victim)),
        _ => Box::new(HlemVmp::new(HlemConfig::adjusted().with_victim_policy(victim))),
    };
    let mut engine = Engine::new(engine_cfg, policy);
    build_comparison_workload(&mut engine, &cfg);
    let r = engine.run();
    (r.spot.interruptions, r.spot.avg_interruption_secs, r.spot.max_interruption_secs)
}

fn main() {
    banner("ABLATION: spot-victim selection policy (paper SIX future work)");
    let mut t = TextTable::new("VICTIM POLICY ABLATION (comparison scenario)")
        .column("Alloc policy", Align::Left)
        .column("Victim policy", Align::Left)
        .column("Interruptions", Align::Right)
        .column("Avg dur (s)", Align::Right)
        .column("Max dur (s)", Align::Right);
    for policy in ["first-fit", "hlem-adjusted"] {
        for (vname, victim) in [
            ("list-order (paper)", VictimPolicy::ListOrder),
            ("youngest", VictimPolicy::Youngest),
            ("smallest-first", VictimPolicy::SmallestFirst),
        ] {
            let (n, avg, max) = run(policy, victim);
            t.push(vec![
                policy.to_string(),
                vname.to_string(),
                n.to_string(),
                format!("{avg:.2}"),
                format!("{max:.2}"),
            ]);
        }
    }
    println!("{}", t.render());
}
