//! §Perf sweep: multi-scenario fan-out throughput.
//!
//! Measures sweep cells/sec on the §VII-E comparison grid at 1 thread vs
//! all available CPUs (the engine is single-threaded by design; the sweep
//! driver's job is to scale *across* runs). Before timing, asserts the
//! headline determinism property: 1-thread and N-thread sweeps serialize
//! to byte-identical artifacts.
//!
//! Results land in `BENCH_sweep.json` at the repo root (regenerate with
//! `cargo bench --bench perf_sweep`; CI refreshes and validates it next
//! to `BENCH_engine.json`). Set `BENCH_FAST=1` for the CI smoke (fewer
//! seeds, shorter horizon).

use cloudmarket::benchkit::{banner, black_box, fast_mode, Bencher};
use cloudmarket::config::scenario::ComparisonConfig;
use cloudmarket::sweep::{self, PolicySpec, SweepSpec};

fn main() {
    banner("PERF: sweep driver fan-out (cells/sec)");
    let fast = fast_mode();
    let seeds = if fast { 2 } else { 4 };
    let horizon = if fast { 600.0 } else { 1_200.0 };
    let scenario = ComparisonConfig { terminate_at: horizon, ..Default::default() };
    let spec = SweepSpec::new(scenario)
        .with_seed_range(20_250_710, seeds)
        .with_policies(PolicySpec::paper());
    let cells = spec.cell_count();
    // Floor of 2 so the 1-vs-N comparison (and the CI row-name check)
    // stays meaningful even on a single-CPU runner.
    let n_threads = sweep::default_threads().max(2);

    // Determinism smoke before timing: the merged output must not depend
    // on the thread count.
    let single = sweep::run(&spec, 1);
    assert_eq!(single.failed(), 0, "sweep cells failed");
    let multi = sweep::run(&spec, n_threads);
    assert_eq!(
        single.cells_csv().to_string(),
        multi.cells_csv().to_string(),
        "sweep cell rows differ between 1 and {n_threads} threads"
    );
    assert_eq!(
        single.aggregate_json().to_string_pretty(),
        multi.aggregate_json().to_string_pretty(),
        "sweep aggregates differ between 1 and {n_threads} threads"
    );
    println!("determinism: 1-thread == {n_threads}-thread output over {cells} cells");

    let mut b = Bencher::heavy();
    b.bench(&format!("sweep {cells} cells [threads=1]"), Some(cells as f64), || {
        black_box(sweep::run(&spec, 1));
    });
    b.bench(
        &format!("sweep {cells} cells [threads={n_threads}]"),
        Some(cells as f64),
        || {
            black_box(sweep::run(&spec, n_threads));
        },
    );
    let rows = b.results();
    let speedup = rows[0].median.as_secs_f64() / rows[1].median.as_secs_f64().max(1e-12);
    println!("    -> fan-out speedup {speedup:.1}x at {n_threads} threads");

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("BENCH_sweep.json");
    b.write_json(&out).expect("writing BENCH_sweep.json");
    println!("wrote {}", out.display());
}
