//! §Perf sweep: multi-scenario fan-out throughput.
//!
//! Measures sweep cells/sec on the §VII-E comparison grid at 1 thread vs
//! all available CPUs (the engine is single-threaded by design; the sweep
//! driver's job is to scale *across* runs). Before timing, asserts the
//! headline determinism property: 1-thread and N-thread sweeps serialize
//! to byte-identical artifacts.
//!
//! On top of the timed throughput rows, one instrumented run of a large
//! (>= 512 cells) mixed-substrate grid records the driver's **phase
//! breakdown** - prebuild-busy vs cell-exec-busy vs merge wall time, plus
//! `first-cell-done` (the effective serial prefix). With lazy worker-side
//! prebuilds the first cell completes after roughly one prebuild + one
//! cell, even though the grid spans dozens of (substrate, seed) prebuild
//! pairs; CI gates on `first-cell-done` staying a small fraction of the
//! wall time.
//!
//! A local-only **scale tier** (skipped under `BENCH_FAST`) times a grid
//! of large trace cells so deep sweep-level cells/sec can be watched
//! outside CI; the CI-gated million-entity numbers live in
//! `perf_engine`'s always-on scale tier.
//!
//! Results land in `BENCH_sweep.json` at the repo root (regenerate with
//! `cargo bench --bench perf_sweep`; CI refreshes and validates it next
//! to `BENCH_engine.json`, and gates cells/sec against the committed
//! baseline - see docs/perf.md). Set `BENCH_FAST=1` for the CI smoke
//! (fewer seeds, shorter horizon).

use std::time::{Duration, Instant};

use cloudmarket::benchkit::{banner, black_box, fast_mode, BenchResult, Bencher};
use cloudmarket::config::scenario::ComparisonConfig;
use cloudmarket::sweep::{self, PolicySpec, ScenarioAxis, Substrate, SweepSpec};
use cloudmarket::vm::InterruptionBehavior;

fn main() {
    banner("PERF: sweep driver fan-out (cells/sec)");
    let fast = fast_mode();
    let seeds = if fast { 2 } else { 4 };
    let horizon = if fast { 600.0 } else { 1_200.0 };
    let scenario = ComparisonConfig { terminate_at: horizon, ..Default::default() };
    let spec = SweepSpec::new(scenario)
        .with_seed_range(20_250_710, seeds)
        .with_policies(PolicySpec::paper());
    let cells = spec.cell_count();
    // Floor of 2 so the 1-vs-N comparison (and the CI row-name check)
    // stays meaningful even on a single-CPU runner.
    let n_threads = sweep::default_threads().max(2);

    // Determinism smoke before timing: the merged output must not depend
    // on the thread count (with lazy prebuilds: nor on which worker wins
    // a prebuild race).
    let single = sweep::run(&spec, 1);
    assert_eq!(single.failed(), 0, "sweep cells failed");
    let multi = sweep::run(&spec, n_threads);
    assert_eq!(
        single.cells_csv().to_string(),
        multi.cells_csv().to_string(),
        "sweep cell rows differ between 1 and {n_threads} threads"
    );
    assert_eq!(
        single.aggregate_json().to_string_pretty(),
        multi.aggregate_json().to_string_pretty(),
        "sweep aggregates differ between 1 and {n_threads} threads"
    );
    println!("determinism: 1-thread == {n_threads}-thread output over {cells} cells");

    let mut b = Bencher::heavy();
    b.bench(&format!("sweep {cells} cells [threads=1]"), Some(cells as f64), || {
        black_box(sweep::run(&spec, 1));
    });
    b.bench(
        &format!("sweep {cells} cells [threads={n_threads}]"),
        Some(cells as f64),
        || {
            black_box(sweep::run(&spec, n_threads));
        },
    );
    let rows = b.results();
    let speedup = rows[0].median.as_secs_f64() / rows[1].median.as_secs_f64().max(1e-12);
    println!("    -> fan-out speedup {speedup:.1}x at {n_threads} threads");

    // --- large mixed-substrate grid: lazy-prebuild phase breakdown ------
    banner("PERF: lazy prebuilds on a large mixed-substrate grid");
    let big_horizon = if fast { 240.0 } else { 420.0 };
    let big_scenario = ComparisonConfig { terminate_at: big_horizon, ..Default::default() };
    // 22 seeds x 3 policies x 2 warnings x 2 behaviors x 2 substrates
    // = 528 cells over 44 distinct (substrate, seed) prebuild pairs.
    let mut big = SweepSpec::new(big_scenario)
        .with_seed_range(20_250_710, 22)
        .with_policies(PolicySpec::paper())
        .with_axis(ScenarioAxis::SpotWarning(vec![2.0, 120.0]))
        .with_axis(ScenarioAxis::SpotBehavior(vec![
            InterruptionBehavior::Hibernate,
            InterruptionBehavior::Terminate,
        ]))
        .with_axis(ScenarioAxis::Substrate(vec![Substrate::Comparison, Substrate::Trace]));
    // Tiny trace substrate so per-seed trace generation stays measurable
    // without dominating the bench.
    big.trace.synth.machines = 10;
    big.trace.synth.days = 0.05;
    big.trace.synth.tasks_per_hour = 120.0;
    big.trace.workload.spot_instances = 20;
    big.trace.workload.spot_durations = vec![300.0, 600.0];
    big.trace.workload.max_trace_vms = 50;
    let big_cells = big.cell_count();
    assert!(big_cells >= 512, "large-grid case must cover >= 512 cells (got {big_cells})");

    let (report, timing) = sweep::run_with_timing(&big, n_threads);
    assert_eq!(report.total(), big_cells);
    assert_eq!(report.failed(), 0, "large-grid sweep cells failed");
    let phase = |name: &str, took: Duration, items: Option<f64>| {
        // Clamp to 1ns so the JSON validator's median_ns > 0 invariant
        // holds even for near-instant phases.
        let took = took.max(Duration::from_nanos(1));
        BenchResult {
            name: format!("sweep {big_cells} cells mixed phase[{name}]"),
            iterations: 1,
            median: took,
            mean: took,
            p95: took,
            min: took,
            items_per_iter: items,
        }
    };
    b.record(phase("wall", timing.wall, Some(big_cells as f64)));
    b.record(phase("prebuild-busy", timing.prebuild_busy, None));
    b.record(phase("cell-exec-busy", timing.cell_busy, None));
    b.record(phase("merge", timing.merge, None));
    b.record(phase("first-cell-done", timing.first_cell_done, None));
    println!(
        "    -> {} prebuilds built lazily on {n_threads} threads; first cell done at {:.1}% \
         of wall ({:?} of {:?})",
        timing.prebuilds_built,
        100.0 * timing.first_cell_done.as_secs_f64() / timing.wall.as_secs_f64().max(1e-12),
        timing.first_cell_done,
        timing.wall,
    );

    // --- scale tier: heavyweight trace cells (local-only) ---------------
    // One timed pass over a grid of *large* trace cells - the per-cell
    // entity counts approach the engine scale tier's regime rather than
    // the smoke-sized grids above. Skipped under BENCH_FAST: CI exercises
    // the million-entity regime through `perf_engine`'s always-on scale
    // tier (which also carries the gated RSS row); this row exists so
    // local runs can watch sweep-level cells/sec at depth. Because it
    // never runs under BENCH_FAST it also never appears in CI-generated
    // BENCH_sweep.json, keeping the CI regression gate's row set stable.
    if !fast {
        banner("PERF: sweep scale tier (large trace cells)");
        let scale_scenario = ComparisonConfig { terminate_at: 2_400.0, ..Default::default() };
        let mut scale = SweepSpec::new(scale_scenario)
            .with_seed_range(20_250_808, 4)
            .with_policies(vec![
                PolicySpec::FirstFit,
                PolicySpec::Hlem { adjusted: true, alpha: -0.5 },
            ])
            .with_axis(ScenarioAxis::Substrate(vec![Substrate::Trace]));
        scale.trace.synth.machines = 500;
        scale.trace.synth.days = 0.25;
        scale.trace.synth.tasks_per_hour = 600.0;
        scale.trace.workload.spot_instances = 500;
        scale.trace.workload.max_trace_vms = 5_000;
        let scale_cells = scale.cell_count();

        let started = Instant::now();
        let report = sweep::run(&scale, n_threads);
        let took = started.elapsed().max(Duration::from_nanos(1));
        assert_eq!(report.failed(), 0, "scale-tier sweep cells failed");
        b.record(BenchResult {
            name: format!("sweep scale tier {scale_cells} cells trace [threads={n_threads}]"),
            iterations: 1,
            median: took,
            mean: took,
            p95: took,
            min: took,
            items_per_iter: Some(scale_cells as f64),
        });
        println!(
            "    -> {scale_cells} large trace cells in {took:?} ({:.2} cells/sec)",
            scale_cells as f64 / took.as_secs_f64().max(1e-12),
        );
    }

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("BENCH_sweep.json");
    b.write_json(&out).expect("writing BENCH_sweep.json");
    println!("wrote {}", out.display());
}
