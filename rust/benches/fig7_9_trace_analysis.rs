//! Bench + regeneration of paper Figs. 7-9 (trace concurrency analysis).
//!
//! Prints the figure series (the deliverable) and times generation +
//! analysis at month scale.

use cloudmarket::benchkit::{banner, black_box, Bencher};
use cloudmarket::experiments::trace_analysis;
use cloudmarket::trace::analysis::{
    fig7_daily_task_concurrency, fig8_daily_cloudlet_concurrency, fig9_hour_of_day_peaks,
};

fn main() {
    banner("FIGS 7-9: trace concurrency analysis (30-day synthetic Borg trace)");
    let trace = trace_analysis::month_trace(42, 200);
    println!(
        "trace: {} machines, {} task submissions, horizon {:.0} days",
        trace.machine_count(),
        trace.task_count(),
        trace.horizon / 86_400.0
    );

    println!("{}", trace_analysis::fig7_table(&trace).render());
    println!("{}", trace_analysis::fig8_table(&trace).render());
    println!("{}", trace_analysis::fig9_table(&trace).render());

    banner("timings");
    let mut b = Bencher::heavy();
    b.bench("generate 30d trace (200 machines)", Some(trace.tasks.len() as f64), || {
        black_box(trace_analysis::month_trace(42, 200));
    });
    b.bench("fig7 daily task concurrency", Some(trace.tasks.len() as f64), || {
        black_box(fig7_daily_task_concurrency(&trace));
    });
    b.bench("fig8 daily cloudlet concurrency", Some(trace.tasks.len() as f64), || {
        black_box(fig8_daily_cloudlet_concurrency(&trace));
    });
    b.bench("fig9 hour-of-day peaks", Some(trace.tasks.len() as f64), || {
        black_box(fig9_hour_of_day_peaks(&trace));
    });
    b.write_json(std::path::Path::new("results/bench_fig7_9.json")).ok();
}
