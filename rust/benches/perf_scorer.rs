//! §Perf L3/RT: HLEM host-scoring latency - pure-rust scorer vs the
//! PJRT-executed AOT artifact, across host-batch sizes.
//!
//! Expected shape: rust wins at small H (no FFI/launch overhead), the
//! artifact amortizes at the full 128-host batch; the crossover is
//! recorded in EXPERIMENTS.md §Perf.

use std::rc::Rc;

use cloudmarket::allocation::scorer::{HostScorer, RustScorer, ScoreInput};
use cloudmarket::benchkit::{banner, black_box, Bencher};
use cloudmarket::runtime::{artifacts, PjrtEngine, PjrtScorer};
use cloudmarket::stats::Rng;

fn random_input(
    rng: &mut Rng,
    n: usize,
) -> (Vec<[f64; 4]>, Vec<[f64; 4]>, Vec<[f64; 4]>, Vec<bool>) {
    let mut caps = Vec::new();
    let mut free = Vec::new();
    let mut spot = Vec::new();
    let mut mask = Vec::new();
    for _ in 0..n {
        let mut c = [0.0; 4];
        let mut f = [0.0; 4];
        let mut s = [0.0; 4];
        for d in 0..4 {
            c[d] = rng.uniform(1.0, 1e5);
            f[d] = c[d] * rng.next_f64();
            s[d] = f[d] * rng.next_f64();
        }
        caps.push(c);
        free.push(f);
        spot.push(s);
        mask.push(true);
    }
    (caps, free, spot, mask)
}

fn main() {
    banner("PERF: HLEM scorer backends (rust vs PJRT artifact)");
    let mut rng = Rng::new(1);
    let mut b = Bencher::new();

    let mut rust = RustScorer::new();
    for &n in &[8usize, 32, 100, 128] {
        let (caps, free, spot, mask) = random_input(&mut rng, n);
        let input =
            ScoreInput { caps: &caps, free: &free, spot_used: &spot, mask: &mask, alpha: -0.5 };
        b.bench(&format!("rust scorer H={n}"), Some(n as f64), || {
            black_box(rust.scores(&input));
        });
    }

    if artifacts::artifacts_available() {
        let engine = Rc::new(PjrtEngine::load_default().expect("loading artifacts"));
        let mut pjrt = PjrtScorer::new(engine);
        for &n in &[8usize, 32, 100, 128] {
            let (caps, free, spot, mask) = random_input(&mut rng, n);
            let input = ScoreInput {
                caps: &caps,
                free: &free,
                spot_used: &spot,
                mask: &mask,
                alpha: -0.5,
            };
            b.bench(&format!("pjrt scorer H={n} (padded to 128)"), Some(n as f64), || {
                black_box(pjrt.scores(&input));
            });
        }
    } else {
        println!("(artifacts not built - run `make artifacts` for the PJRT side)");
    }
    b.write_json(std::path::Path::new("results/bench_scorer.json")).ok();
}
