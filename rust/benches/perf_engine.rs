//! §Perf L3: DES kernel and end-to-end simulation throughput.
//!
//! - event queue push/pop throughput (the kernel's fundamental cost),
//! - end-to-end events/sec on the comparison scenario (the headline
//!   "simulator speed" number vs the paper's 1.5 days per simulated day),
//! - allocation decision latency per policy at 100 hosts.

use cloudmarket::allocation::{AllocationPolicy, BestFit, FirstFit, HlemVmp, RoundRobin, WorstFit};
use cloudmarket::benchkit::{banner, black_box, Bencher};
use cloudmarket::config::scenario::{build_comparison_workload, ComparisonConfig};
use cloudmarket::core::{EntityId, EventQueue, SimEvent};
use cloudmarket::engine::{Engine, EngineConfig};
use cloudmarket::stats::Rng;

fn main() {
    banner("PERF: DES kernel + end-to-end engine");
    let mut b = Bencher::new();

    // --- event queue ----------------------------------------------------
    let n_events = 100_000usize;
    let mut rng = Rng::new(3);
    let times: Vec<f64> = (0..n_events).map(|_| rng.uniform(0.0, 1e6)).collect();
    b.bench("event queue push+pop 100k", Some(n_events as f64), || {
        let mut q: EventQueue<u32> = EventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            q.push(SimEvent::new(t, EntityId::Kernel, EntityId::Kernel, i as u32));
        }
        let mut count = 0;
        while q.pop().is_some() {
            count += 1;
        }
        black_box(count);
    });

    // --- allocation decision latency ------------------------------------
    let mut engine = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
    build_comparison_workload(&mut engine, &ComparisonConfig::default());
    // Commit ~40% load so policies see a realistic mixed cluster while
    // every host keeps some headroom (a feasible candidate set forces the
    // HLEM scoring pipeline to actually run each decision).
    let world = &mut engine.world;
    let vm_ids: Vec<usize> = (0..world.vms.len()).collect();
    let mut placed = 0;
    for &v in &vm_ids {
        if placed >= 350 {
            break;
        }
        let spec = world.vms[v].spec;
        if let Some(h) = (0..world.hosts.len()).find(|&h| {
            let host = &world.hosts[h];
            host.free_pes() > spec.pes + 2 && host.fits(spec.pes, spec.ram, spec.bw, spec.storage)
        }) {
            world.hosts[h].commit(v, spec.pes, spec.ram, spec.bw, spec.storage);
            placed += 1;
        }
    }
    // Probe with a small VM so every policy sees many candidates.
    let probe = vm_ids
        .iter()
        .copied()
        .find(|&v| world.vms[v].spec.pes <= 2 && world.vms[v].host.is_none())
        .expect("small probe vm");
    let world = &engine.world;
    {
        // Sanity: the probe must have a large candidate set.
        let feasible = world
            .active_hosts()
            .filter(|h| {
                let s = world.vms[probe].spec;
                h.fits(s.pes, s.ram, s.bw, s.storage)
            })
            .count();
        println!("(probe candidate hosts: {feasible})");
        assert!(feasible > 50);
    }
    let mut policies: Vec<Box<dyn AllocationPolicy>> = vec![
        Box::new(FirstFit::new()),
        Box::new(BestFit::new()),
        Box::new(WorstFit::new()),
        Box::new(RoundRobin::new()),
        Box::new(HlemVmp::plain()),
        Box::new(HlemVmp::adjusted()),
    ];
    for p in policies.iter_mut() {
        let name = p.name();
        b.bench(&format!("select_host [{name}] 100 hosts"), Some(1.0), || {
            black_box(p.select_host(world, probe, 100.0));
        });
    }

    // --- end-to-end events/sec -------------------------------------------
    banner("end-to-end scenario throughput");
    let mut hb = Bencher::heavy();
    let r = {
        let mut engine = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        build_comparison_workload(&mut engine, &ComparisonConfig::default());
        engine.run()
    };
    let events = r.events_processed as f64;
    hb.bench("comparison scenario e2e (first-fit)", Some(events), || {
        let mut engine = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        build_comparison_workload(&mut engine, &ComparisonConfig::default());
        black_box(engine.run());
    });
    println!("(events per e2e run: {events})");
    b.write_json(std::path::Path::new("results/bench_engine.json")).ok();
    hb.write_json(std::path::Path::new("results/bench_engine_e2e.json")).ok();
}
