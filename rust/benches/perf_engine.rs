//! §Perf L3: DES kernel and end-to-end simulation throughput.
//!
//! - event queue push/pop throughput (the kernel's fundamental cost),
//!   including the allocation-free `pop_due_into` batch drain,
//! - allocation decision latency per policy at 100 / 1 000 / 10 000
//!   hosts, indexed hot path vs. the pre-index linear scan (the scan
//!   baseline is the exact pre-index implementation, kept in `World` as
//!   the `_scan` oracles; parity is asserted before timing),
//! - end-to-end events/sec on the comparison scenario (the headline
//!   "simulator speed" number vs the paper's 1.5 days per simulated day),
//! - the million-entity scale tier: 100 000 hosts / 1.1 M committed VMs
//!   exercising the SoA hot state (`engine::soa`), with O(1)
//!   `state_sample` vs the walking `_scan` oracle, a churn+sample
//!   throughput row, and the process peak RSS (VmHWM) recorded as a
//!   byte-valued row - CI gates both against the committed baseline
//!   (see docs/perf.md).
//!
//! All results land in `BENCH_engine.json` at the repo root (the
//! decision-latency trajectory CI validates). Set `BENCH_FAST=1` to skip
//! the 10 000-host decision tier (CI smoke); the scale tier always runs -
//! it is the row CI's RSS ceiling and throughput gates key on.
//!
//! The decision world is first-fit-shaped: the head of the cluster is
//! packed solid (free_pes = 0) and only the tail keeps headroom, which is
//! what a loaded cluster looks like and is exactly the case where the
//! pre-index scans waste their time walking infeasible hosts.

use std::time::{Duration, Instant};

use cloudmarket::allocation::{AllocationPolicy, BestFit, FirstFit, HlemVmp, RoundRobin, WorstFit};
use cloudmarket::benchkit::{banner, black_box, fast_mode, BenchResult, Bencher};
use cloudmarket::config::scenario::{build_comparison_workload, ComparisonConfig};
use cloudmarket::core::{EntityId, EventQueue, HeapEventQueue, SimEvent};
use cloudmarket::engine::{Engine, EngineConfig, World};
use cloudmarket::infra::HostSpec;
use cloudmarket::stats::Rng;
use cloudmarket::vm::{SpotConfig, Vm, VmId, VmSpec, VmState};

/// Peak resident set of this process in bytes (`VmHWM` from
/// `/proc/self/status`). `None` off Linux - the RSS row is then skipped
/// (CI runs on Linux, where the row is required and gated).
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// A cluster of `n_hosts` with the head packed solid, spot VMs sprinkled
/// through the packed region, and ~8% tail headroom; plus a small probe
/// VM whose placement decision every policy must answer.
fn decision_world(n_hosts: usize) -> (World, VmId) {
    let mut w = World::new();
    let dc = w.add_datacenter("dc", 1.0);
    for i in 0..n_hosts {
        let pes = [16u32, 32, 64][i % 3];
        w.add_host(dc, HostSpec::new(pes, 1000.0, 262_144.0, 40_000.0, 4_000_000.0), 0.0);
    }
    // Pack the head of the cluster completely (first-fit-shaped load):
    // the decision hot path must skip all of it.
    let full = n_hosts * 92 / 100;
    for h in 0..full {
        let pes = w.hosts[h].spec.pes;
        if h % 3 == 0 {
            // Half spot, half on-demand: keeps the spot-usage vectors and
            // the spot-host set populated (the HLEM adjusted-score path).
            let sp = w.add_vm(Vm::spot(0, VmSpec::new(1000.0, pes / 2), SpotConfig::hibernate()));
            w.commit_vm(h, sp);
            let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, pes - pes / 2)));
            w.commit_vm(h, od);
        } else {
            let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, pes)));
            w.commit_vm(h, od);
        }
    }
    // Tail hosts keep half their PEs free (the feasible candidate set).
    for h in full..n_hosts {
        let pes = w.hosts[h].spec.pes;
        let od = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, pes / 2)));
        w.commit_vm(h, od);
    }
    w.check_index().expect("index consistent after workload build");
    let probe = w.add_vm(Vm::on_demand(0, VmSpec::new(1000.0, 2)));
    (w, probe)
}

fn main() {
    banner("PERF: DES kernel + end-to-end engine");
    let fast = fast_mode();
    let mut b = Bencher::new();

    // --- event queue: slab store vs BinaryHeap oracle -------------------
    // A realistic payload size (Tag-shaped, ~48 bytes): the slab queue's
    // win is not moving payloads through heap sifts, so a u32 payload
    // would understate it.
    type FatPayload = [u64; 6];
    let n_events = 100_000usize;
    let mut rng = Rng::new(3);
    let times: Vec<f64> = (0..n_events).map(|_| rng.uniform(0.0, 1e6)).collect();

    // Ordering parity before timing: slab and oracle must agree on the
    // full (time, seq) pop order over the random schedule.
    {
        let mut q: EventQueue<u32> = EventQueue::new();
        let mut oracle: HeapEventQueue<u32> = HeapEventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            q.push(SimEvent::new(t, EntityId::Kernel, EntityId::Kernel, i as u32));
            oracle.push(SimEvent::new(t, EntityId::Kernel, EntityId::Kernel, i as u32));
        }
        loop {
            match (q.pop(), oracle.pop()) {
                (None, None) => break,
                (Some(a), Some(b)) => assert_eq!(
                    (a.time, a.seq, a.data),
                    (b.time, b.seq, b.data),
                    "slab/oracle pop-order parity violated"
                ),
                (a, b) => panic!("queue lengths diverged: {:?} vs {:?}", a.is_some(), b.is_some()),
            }
        }
        println!("parity: slab queue == BinaryHeap oracle over {n_events} random events");
    }

    let slab_row = b.bench("event queue push+pop 100k [slab]", Some(n_events as f64), || {
        let mut q: EventQueue<FatPayload> = EventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            q.push(SimEvent::new(t, EntityId::Kernel, EntityId::Kernel, [i as u64; 6]));
        }
        let mut count = 0;
        while q.pop().is_some() {
            count += 1;
        }
        black_box(count);
    });
    let oracle_row =
        b.bench("event queue push+pop 100k [heap-oracle]", Some(n_events as f64), || {
            let mut q: HeapEventQueue<FatPayload> = HeapEventQueue::new();
            for (i, &t) in times.iter().enumerate() {
                q.push(SimEvent::new(t, EntityId::Kernel, EntityId::Kernel, [i as u64; 6]));
            }
            let mut count = 0;
            while q.pop().is_some() {
                count += 1;
            }
            black_box(count);
        });
    println!(
        "    -> slab queue {:.2}x over BinaryHeap oracle",
        oracle_row.median.as_secs_f64() / slab_row.median.as_secs_f64().max(1e-12)
    );
    let mut batch: Vec<SimEvent<u32>> = Vec::new();
    b.bench("event queue pop_due_into batch drain 100k", Some(n_events as f64), || {
        let mut q: EventQueue<u32> = EventQueue::new();
        for (i, &t) in times.iter().enumerate() {
            q.push(SimEvent::new(t, EntityId::Kernel, EntityId::Kernel, i as u32));
        }
        let mut count = 0;
        while let Some(t) = q.next_time() {
            batch.clear();
            q.pop_due_into(t, &mut batch);
            count += batch.len();
        }
        black_box(count);
    });

    // --- allocation decision latency: indexed vs pre-index scan ---------
    banner("decision latency (indexed placement index vs linear scan)");
    let factories: Vec<(&'static str, fn(bool) -> Box<dyn AllocationPolicy>)> = vec![
        ("first-fit", |scan| Box::new(FirstFit::new().with_scan_mode(scan))),
        ("best-fit", |scan| Box::new(BestFit::new().with_scan_mode(scan))),
        ("worst-fit", |scan| Box::new(WorstFit::new().with_scan_mode(scan))),
        ("hlem-vmp", |scan| Box::new(HlemVmp::plain().with_scan_mode(scan))),
        ("hlem-vmp-adjusted", |scan| Box::new(HlemVmp::adjusted().with_scan_mode(scan))),
    ];
    let sizes: &[usize] = if fast { &[100, 1_000] } else { &[100, 1_000, 10_000] };
    const CALLS: usize = 64;
    for &n in sizes {
        let (world, probe) = decision_world(n);
        for (name, make) in &factories {
            let mut indexed = make(false);
            let mut scanned = make(true);
            // Placement parity before timing: both modes must agree.
            assert_eq!(
                indexed.select_host(&world, probe, 100.0),
                scanned.select_host(&world, probe, 100.0),
                "index/scan decision parity violated for {name} at {n} hosts"
            );
            let ri = b.bench(
                &format!("select_host[{name}][indexed] {n} hosts"),
                Some(CALLS as f64),
                || {
                    for _ in 0..CALLS {
                        black_box(indexed.select_host(&world, probe, 100.0));
                    }
                },
            );
            let rs = b.bench(
                &format!("select_host[{name}][scan] {n} hosts"),
                Some(CALLS as f64),
                || {
                    for _ in 0..CALLS {
                        black_box(scanned.select_host(&world, probe, 100.0));
                    }
                },
            );
            let speedup =
                rs.median.as_secs_f64() / ri.median.as_secs_f64().max(1e-12);
            println!("    -> {name} @ {n} hosts: index speedup {speedup:.1}x over scan");
        }
        // RoundRobin has no indexed variant (positional cursor); timed for
        // the record.
        let mut rr = RoundRobin::new();
        b.bench(&format!("select_host[round-robin][cursor] {n} hosts"), Some(CALLS as f64), || {
            for _ in 0..CALLS {
                black_box(rr.select_host(&world, probe, 100.0));
            }
        });
    }

    // --- end-to-end events/sec -------------------------------------------
    banner("end-to-end scenario throughput");
    let mut hb = Bencher::heavy();
    let r = {
        let mut engine = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        build_comparison_workload(&mut engine, &ComparisonConfig::default());
        engine.run()
    };
    let events = r.events_processed as f64;
    hb.bench("comparison scenario e2e (first-fit)", Some(events), || {
        let mut engine = Engine::new(EngineConfig::default(), Box::new(FirstFit::new()));
        build_comparison_workload(&mut engine, &ComparisonConfig::default());
        black_box(engine.run());
    });
    println!("(events per e2e run: {events})");

    // --- scale tier: 100k hosts / 1M+ VMs --------------------------------
    // Not gated on `fast`: this tier is exactly what CI's RSS ceiling and
    // scale-throughput gates consume, so the BENCH_FAST smoke must still
    // produce it. The workload uses integral-MB RAM values only, so the
    // incremental counters are required to stay on the exact O(1) path
    // (`sample_is_incremental`) for the whole tier.
    banner("scale tier: 100k hosts / 1M+ VMs (SoA hot state, O(1) sampling)");
    const SCALE_HOSTS: usize = 100_000;
    const VMS_PER_HOST: usize = 11;
    let t0 = Instant::now();
    let mut w = World::new();
    let dc = w.add_datacenter("dc", 1.0);
    for _ in 0..SCALE_HOSTS {
        w.add_host(dc, HostSpec::new(16, 1000.0, 65_536.0, 40_000.0, 4_000_000.0), 0.0);
    }
    let mut n_vms = 0usize;
    for h in 0..SCALE_HOSTS {
        for k in 0..VMS_PER_HOST {
            let spec = VmSpec::new(1000.0, 1).with_ram(512.0).with_bw(10.0).with_storage(100.0);
            // One spot VM per host keeps every spot-usage vector and the
            // spot-host set populated at full scale.
            let vm = if k == 0 {
                w.add_vm(Vm::spot(0, spec, SpotConfig::hibernate()))
            } else {
                w.add_vm(Vm::on_demand(0, spec))
            };
            w.commit_vm(h, vm);
            w.transition_vm(vm, VmState::Running);
            n_vms += 1;
        }
    }
    let build = t0.elapsed().max(Duration::from_nanos(1));
    assert!(n_vms >= 1_000_000, "scale tier must commit at least 1M VMs (got {n_vms})");
    assert!(
        w.sample_is_incremental(),
        "integral-MB scale workload must stay on the O(1) RAM path"
    );
    assert!(
        w.state_sample().bits_eq(&w.state_sample_scan()),
        "incremental/scan sample divergence at scale"
    );
    b.record(BenchResult {
        name: format!("scale tier build {SCALE_HOSTS} hosts / {n_vms} vms"),
        iterations: 1,
        median: build,
        mean: build,
        p95: build,
        min: build,
        items_per_iter: Some(n_vms as f64),
    });

    // O(1) sampling vs the walking oracle at scale. The inner loop keeps
    // the per-iteration time measurable for the incremental path.
    const SAMPLE_CALLS: usize = 4_096;
    let ri = b.bench(
        &format!("state_sample[incremental] {SCALE_HOSTS} hosts"),
        Some(SAMPLE_CALLS as f64),
        || {
            for _ in 0..SAMPLE_CALLS {
                black_box(w.state_sample());
            }
        },
    );
    let rs = b.bench(&format!("state_sample[scan-oracle] {SCALE_HOSTS} hosts"), Some(1.0), || {
        black_box(w.state_sample_scan());
    });
    println!(
        "    -> incremental sample {:.0}x over the walking oracle at {SCALE_HOSTS} hosts",
        rs.median.as_secs_f64()
            / (ri.median.as_secs_f64() / SAMPLE_CALLS as f64).max(1e-12)
    );

    // Churn+sample throughput: release + re-commit one resident VM and
    // take a sample, hopping across the cluster - the steady-state
    // mutation pattern of a big run (index update, SoA maintenance, spot
    // fold extend/rebuild, O(1) sample). This is the scale-tier
    // cells/sec row CI gates against the committed baseline.
    const CHURN: usize = 2_048;
    let mut cursor = 0usize;
    b.bench(
        &format!("scale tier churn+sample {SCALE_HOSTS} hosts / {n_vms} vms"),
        Some(CHURN as f64),
        || {
            for _ in 0..CHURN {
                let h = cursor % SCALE_HOSTS;
                let vm = w.hosts[h].vms[0];
                w.release_vm(h, vm);
                w.commit_vm(h, vm);
                black_box(w.state_sample());
                cursor = cursor.wrapping_add(7_919);
            }
        },
    );
    w.check_index().expect("index + SoA mirrors consistent after scale churn");

    // Peak RSS of the whole bench process (the scale world dominates),
    // encoded as a byte-valued row: median_ns == bytes, iterations == 1.
    // CI fails when this exceeds the committed baseline by >20%.
    match peak_rss_bytes() {
        Some(bytes) => {
            let d = Duration::from_nanos(bytes.max(1));
            b.record(BenchResult {
                name: format!("scale tier max RSS bytes {SCALE_HOSTS} hosts / {n_vms} vms"),
                iterations: 1,
                median: d,
                mean: d,
                p95: d,
                min: d,
                items_per_iter: None,
            });
            println!("    -> peak RSS {:.0} MB (VmHWM)", bytes as f64 / (1024.0 * 1024.0));
        }
        None => println!("(VmHWM unavailable on this platform; RSS row skipped)"),
    }
    drop(w);

    // --- trajectory file --------------------------------------------------
    b.merge(&hb);
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .join("BENCH_engine.json");
    b.write_json(&out).expect("writing BENCH_engine.json");
    println!("wrote {}", out.display());
}
