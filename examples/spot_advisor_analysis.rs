//! Spot-advisor correlation analysis (paper §VII-F, Fig. 16).
//!
//! Builds the 389-instance-type dataset (synthetic unless a real advisor
//! JSON is passed as argv[1]) and prints the feature <-> interruption-
//! frequency association table using Theil's U, the correlation ratio and
//! Pearson correlation - the dython.nominal measures of the paper.
//!
//! Run: `cargo run --release --example spot_advisor_analysis [advisor.json]`

use cloudmarket::experiments::advisor;

fn main() {
    let path = std::env::args().nth(1).map(std::path::PathBuf::from);
    let ds = advisor::dataset(path.as_deref(), 7);

    println!(
        "dataset: {} instance types across {} families / {} categories",
        ds.rows.len(),
        ds.family_names.len(),
        ds.category_names.len()
    );
    println!("{}", advisor::class_distribution_table(&ds).render());
    println!("{}", advisor::fig16_table(&ds).render());

    // The paper's headline ordering must hold: exact type > family >
    // coarse machine category; nuisance features negligible.
    let assoc = ds.fig16_associations();
    let get = |n: &str| assoc.iter().find(|r| r.feature == n).unwrap().value;
    assert!(get("instance_type") > get("instance_family"));
    assert!(get("instance_family") > get("machine_category"));
    assert!(get("day") < 0.1);
    println!(
        "spot_advisor_analysis OK: type {:.2} > family {:.2} > category {:.2} (paper: 0.38/0.33/0.18)",
        get("instance_type"),
        get("instance_family"),
        get("machine_category")
    );
}
