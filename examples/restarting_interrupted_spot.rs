//! RestartingInterruptedSpot - the paper's §VII-B(b) test case.
//!
//! Several spot instances with persistent requests start first and fill
//! two hosts; a wave of on-demand instances arrives 10 s later and
//! preempts them; the spots hibernate, are resubmitted when the on-demand
//! wave completes, and finish. Reproduces the Fig. 5/6 output tables
//! (including average interruption times).
//!
//! Run: `cargo run --release --example restarting_interrupted_spot`

use cloudmarket::allocation::HlemVmp;
use cloudmarket::cloudlet::Cloudlet;
use cloudmarket::engine::{Engine, EngineConfig};
use cloudmarket::infra::HostSpec;
use cloudmarket::metrics::tables;
use cloudmarket::vm::{SpotConfig, Vm, VmSpec, VmState, VmType};

fn main() {
    let mut cfg = EngineConfig::default();
    cfg.min_dt = 0.5;
    cfg.vm_destruction_delay = 1.0;
    let mut engine = Engine::new(cfg, Box::new(HlemVmp::plain()));
    let dc = engine.add_datacenter("dc0", 1.0);
    // Two 8-PE hosts (the paper's Fig. 5 shows hosts with 8 CPUs).
    for _ in 0..2 {
        engine.add_host(dc, HostSpec::new(8, 1000.0, 32_768.0, 10_000.0, 1_000_000.0));
    }

    // Three 4-PE spot instances with persistent requests + hibernation.
    let spot_cfg = SpotConfig::hibernate()
        .with_min_running(0.0)
        .with_warning(0.0)
        .with_hibernation_timeout(60.0);
    let mut spots = Vec::new();
    for _ in 0..3 {
        let spec = VmSpec::new(1000.0, 4).with_ram(1_024.0);
        let vm = engine.submit_vm(Vm::spot(0, spec, spot_cfg).with_persistent(60.0));
        // 44_000 MI at 4000 MIPS -> 11 s of work.
        engine.submit_cloudlet(Cloudlet::new(0, 44_000.0, 4).with_vm(vm));
        spots.push(vm);
    }

    // Five 4-PE on-demand instances arrive at t=10 (22 s of work each);
    // they need 20 PEs > the 16 available, so spots are interrupted and
    // the fifth one waits.
    let mut ods = Vec::new();
    for _ in 0..5 {
        let spec = VmSpec::new(1000.0, 4).with_ram(1_024.0);
        let vm = engine
            .submit_vm(Vm::on_demand(0, spec).with_persistent(120.0).with_delay(10.0));
        engine.submit_cloudlet(Cloudlet::new(0, 88_000.0, 4).with_vm(vm));
        ods.push(vm);
    }

    engine.terminate_at(200.0);
    let report = engine.run();

    let all: Vec<usize> = (0..engine.world.vms.len()).collect();
    println!("{}", tables::dynamic_vm_table(&engine.world, &all).render());
    println!("{}", tables::spot_vm_table(&engine.world, &all).render());
    println!("{}", tables::execution_table(&engine.world, &all).render());
    println!("{}", report.render());

    // Invariants of the scenario.
    let finished_spots = spots
        .iter()
        .filter(|&&v| engine.world.vms[v].state == VmState::Finished)
        .count();
    let interrupted = spots.iter().filter(|&&v| engine.world.vms[v].interruptions > 0).count();
    assert!(interrupted >= 1, "at least one spot must be interrupted");
    assert_eq!(finished_spots, 3, "all spots must eventually finish");
    assert!(
        engine
            .world
            .vms
            .iter()
            .filter(|v| v.vm_type == VmType::OnDemand)
            .all(|v| v.state == VmState::Finished),
        "all on-demand VMs must finish"
    );
    assert!(report.spot.redeployments >= 1);
    println!(
        "\nrestarting_interrupted_spot OK: {interrupted} spots interrupted, all resumed and finished"
    );
}
