//! RandomlyGeneratedInstances - the paper's §VII-B(a) test case.
//!
//! Instances (spot and on-demand, randomized profiles) are generated
//! dynamically during the run; when on-demand capacity runs short,
//! running spot instances (terminate behavior) are interrupted and appear
//! as TERMINATED in the output table - the paper's Fig. 5 scenario.
//!
//! Run: `cargo run --release --example randomly_generated_instances`

use cloudmarket::allocation::FirstFit;
use cloudmarket::cloudlet::Cloudlet;
use cloudmarket::engine::{Engine, EngineConfig};
use cloudmarket::infra::HostSpec;
use cloudmarket::metrics::tables;
use cloudmarket::stats::Rng;
use cloudmarket::vm::{SpotConfig, Vm, VmSpec, VmState, VmType};

fn main() {
    let mut cfg = EngineConfig::default();
    cfg.min_dt = 0.5;
    cfg.vm_destruction_delay = 1.0;
    let mut engine = Engine::new(cfg, Box::new(FirstFit::new()));
    let dc = engine.add_datacenter("dc0", 1.0);
    for _ in 0..4 {
        engine.add_host(dc, HostSpec::new(8, 1000.0, 32_768.0, 10_000.0, 1_000_000.0));
    }

    // "A clockTickListener dynamically generates new VM instances during
    // simulation runtime" - equivalently, we pre-draw the random arrival
    // schedule with a seeded RNG (identical distribution, deterministic).
    let mut rng = Rng::new(7);
    let spot_cfg = SpotConfig::terminate().with_min_running(0.0).with_warning(1.0);
    let mut n_spot = 0;
    let mut n_od = 0;
    for _ in 0..40 {
        let arrival = rng.uniform(0.0, 60.0);
        let pes = rng.range_u64(1, 4) as u32;
        let spec = VmSpec::new(1000.0, pes).with_ram(512.0 * pes as f64);
        let work = rng.uniform(10.0, 40.0); // seconds of execution
        let length = work * 1000.0 * pes as f64;
        let vm = if rng.chance(0.4) {
            n_spot += 1;
            engine.submit_vm(Vm::spot(0, spec, spot_cfg).with_delay(arrival))
        } else {
            n_od += 1;
            engine
                .submit_vm(Vm::on_demand(0, spec).with_persistent(30.0).with_delay(arrival))
        };
        engine.submit_cloudlet(Cloudlet::new(0, length, pes).with_vm(vm));
    }

    engine.terminate_at(150.0);
    let report = engine.run();

    let all: Vec<usize> = (0..engine.world.vms.len()).collect();
    println!("{}", tables::dynamic_vm_table(&engine.world, &all).render());
    println!("{}", report.render());

    let terminated_spots = engine
        .world
        .vms
        .iter()
        .filter(|v| v.vm_type == VmType::Spot && v.state == VmState::Terminated)
        .count();
    println!(
        "\nrandomly_generated_instances OK: {n_spot} spots / {n_od} on-demand generated, \
         {terminated_spots} spots TERMINATED by capacity contention"
    );
    assert!(report.spot.interruptions > 0, "scenario should produce interruptions");
}
