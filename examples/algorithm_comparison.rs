//! Algorithm comparison (paper §VII-E, Figs. 13-15 + Tables II-III).
//!
//! Runs the identical Table II/III workload under First-Fit, HLEM-VMP and
//! the spot-load-adjusted HLEM-VMP, printing the interruption counts
//! (Fig. 14), interruption durations (Fig. 15) and writing the active-
//! instance series (Fig. 13) as CSV.
//!
//! Run: `cargo run --release --example algorithm_comparison`

use cloudmarket::config::catalog;
use cloudmarket::config::scenario::ComparisonConfig;
use cloudmarket::experiments::compare;

fn main() {
    println!("{}", catalog::host_table().render());
    println!("{}", catalog::vm_table().render());

    let cfg = ComparisonConfig::default();
    eprintln!("running 3 policies over the Table II/III workload (seed {}) ...", cfg.seed);
    let outcomes = compare::run_all(&cfg);

    println!("{}", compare::fig14_table(&outcomes).render());
    println!("{}", compare::fig15_table(&outcomes).render());
    println!("{}", compare::shape_summary(&outcomes));

    let out_dir = std::path::PathBuf::from("results");
    compare::fig13_csv(&outcomes)
        .write_file(&out_dir.join("fig13_active_instances.csv"))
        .expect("writing fig13 csv");
    println!("\nwrote {}", out_dir.join("fig13_active_instances.csv").display());

    // Aggregate over 5 seeds for a noise-robust ordering check.
    eprintln!("aggregating over 5 seeds ...");
    let aggs = compare::run_multi(&cfg, 5);
    println!("{}", compare::aggregate_table(&aggs).render());

    let get = |name: &str| aggs.iter().find(|a| a.policy == name).unwrap();
    let ff = get("first-fit").mean_interruptions;
    let adj = get("hlem-vmp-adjusted").mean_interruptions;
    assert!(
        adj < ff,
        "paper shape: adjusted HLEM ({adj:.1}) must average fewer interruptions than First-Fit ({ff:.1})"
    );
    println!("\nalgorithm_comparison OK: adjusted HLEM averages {adj:.1} vs First-Fit {ff:.1}");
}
