//! END-TO-END DRIVER: the cluster-trace simulation (paper §VII-C/D).
//!
//! Exercises the full system on a real (synthetic Borg-like) workload:
//! trace generation -> CSV round-trip through the Google-trace reader ->
//! machine events as host add/remove -> task grouping into VMs ->
//! injected spot instances -> full DES run with interruption/hibernation
//! -> Fig. 12 series + §VII-D statistics + Figs. 10-11 self-profile.
//! Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example cluster_trace`
//! Scale knobs: CM_MACHINES, CM_DAYS, CM_SPOTS, CM_MAX_VMS env vars.

use cloudmarket::experiments::trace_sim::{self, TraceSimConfig};
use cloudmarket::trace::reader;
use cloudmarket::trace::synth::TraceGenerator;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let mut cfg = TraceSimConfig::default();
    cfg.synth.machines = env_usize("CM_MACHINES", 200);
    cfg.synth.days = env_f64("CM_DAYS", 2.0);
    cfg.workload.spot_instances = env_usize("CM_SPOTS", 2_000);
    cfg.workload.max_trace_vms = env_usize("CM_MAX_VMS", 20_000);

    // 1. Generate the trace and round-trip it through the CSV reader to
    //    prove the Google-trace ingestion path works end to end.
    eprintln!(
        "generating trace: {} machines x {:.1} days ...",
        cfg.synth.machines, cfg.synth.days
    );
    let trace = TraceGenerator::new(cfg.synth.clone()).generate();
    let dir = std::env::temp_dir().join("cloudmarket_trace_csv");
    reader::write_trace_dir(&trace, &dir).expect("writing trace CSVs");
    let (reread, stats) = reader::read_trace_dir(&dir).expect("reading trace CSVs");
    assert_eq!(reread.tasks.len(), trace.tasks.len(), "CSV round-trip lost events");
    eprintln!(
        "trace reader: {} machine rows, {} task rows, {} malformed, {} bindings resolved",
        stats.machine_rows, stats.task_rows, stats.malformed_rows, stats.resolved_bindings
    );

    // 2. Run the simulation (uses the same generator config internally).
    eprintln!(
        "simulating with {} injected spots (cap {} trace VMs) ...",
        cfg.workload.spot_instances, cfg.workload.max_trace_vms
    );
    let out = trace_sim::run(&cfg);

    // 3. Report: §VII-D table, Fig. 12 chart + CSV, Figs. 10-11 profile.
    println!("{}", trace_sim::results_table(&out).render());
    println!("{}", out.series.ascii_chart("spot_running", 100, 12));
    println!("{}", out.series.ascii_chart("od_running", 100, 12));

    let out_dir = std::path::PathBuf::from("results");
    trace_sim::fig12_csv(&out)
        .write_file(&out_dir.join("fig12_active_instances.csv"))
        .expect("writing fig12 csv");
    println!("wrote {}", out_dir.join("fig12_active_instances.csv").display());
    if let Some(prof) = &out.selfprof {
        prof.to_csv()
            .write_file(&out_dir.join("fig10_11_selfprofile.csv"))
            .expect("writing selfprofile csv");
        println!(
            "figs 10-11 self-profile: cpu peak {:.0}%, rss peak {:.0} MB, {} samples -> {}",
            prof.max_of("cpu_pct").unwrap_or(0.0),
            prof.max_of("rss_mb").unwrap_or(0.0),
            prof.len(),
            out_dir.join("fig10_11_selfprofile.csv").display()
        );
    }

    // End-to-end sanity: the run must exhibit the paper's dynamics.
    let s = &out.report.spot;
    assert!(out.report.events_processed > 1_000, "simulation too small");
    assert!(s.total_spot as usize == cfg.workload.spot_instances);
    assert!(
        s.interrupted_vms > 0,
        "trace load must interrupt some spot instances"
    );
    assert!(
        s.redeployments > 0,
        "hibernated spots must recover in load dips (paper Fig. 12)"
    );
    println!(
        "\ncluster_trace OK: {} events, {} spot interruptions, {} redeployments, wall {:?}",
        out.report.events_processed, s.interruptions, s.redeployments, out.report.wall
    );
}
