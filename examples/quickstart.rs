//! Quickstart: the paper's §VII-A minimal example, step by step.
//!
//! One datacenter with one host; a spot instance (hibernate-on-interrupt)
//! starts immediately, a delayed on-demand instance preempts it at t=10,
//! and the spot resumes once the on-demand workload completes - the exact
//! lifecycle of the paper's Listings 1-12 and Figs. 5-6.
//!
//! Run: `cargo run --release --example quickstart`

use cloudmarket::allocation::HlemVmp;
use cloudmarket::cloudlet::Cloudlet;
use cloudmarket::engine::{Engine, EngineConfig};
use cloudmarket::infra::HostSpec;
use cloudmarket::metrics::tables;
use cloudmarket::vm::{SpotConfig, Vm, VmSpec};

fn main() {
    // Listing 2: new CloudSim(0.5); simulation.terminateAt(70).
    let mut cfg = EngineConfig::default();
    cfg.min_dt = 0.5;
    cfg.vm_destruction_delay = 1.0; // Listing 5: setVmDestructionDelay(1)
    let mut engine = Engine::new(cfg, Box::new(HlemVmp::plain()));

    // Listing 3-4: one host (2 PEs x 1000 MIPS, 2 GB RAM), DynamicAllocationHLEM.
    let dc = engine.add_datacenter("dc0", 1.0);
    engine.add_host(dc, HostSpec::new(2, 1000.0, 2_048.0, 10_000.0, 1_000_000.0));

    // Listing 6: SpotInstance(1000, 2) with HIBERNATE behavior.
    let spot_cfg = SpotConfig::hibernate()
        .with_min_running(0.0)
        .with_warning(0.0)
        .with_hibernation_timeout(100.0);
    let spot_spec =
        VmSpec::new(1000.0, 2).with_ram(512.0).with_bw(1000.0).with_storage(10_000.0);
    let spot = engine.submit_vm(Vm::spot(0, spot_spec, spot_cfg).with_persistent(60.0));

    // Listing 7: OnDemandInstance(1000, 2) with setSubmissionDelay(10).
    let od_spec =
        VmSpec::new(1000.0, 2).with_ram(512.0).with_bw(1000.0).with_storage(10_000.0);
    let od = engine.submit_vm(Vm::on_demand(0, od_spec).with_delay(10.0));

    // Listing 8: cloudlets (20000 MI over 2 PEs, UtilizationModelFull).
    engine.submit_cloudlet(Cloudlet::new(0, 20_000.0, 2).with_sizes(300.0, 300.0).with_vm(spot));
    engine.submit_cloudlet(Cloudlet::new(0, 20_000.0, 2).with_sizes(300.0, 300.0).with_vm(od));

    engine.terminate_at(70.0);
    let report = engine.run();

    // Listing 12: output tables.
    let all: Vec<usize> = (0..engine.world.vms.len()).collect();
    println!("{}", tables::dynamic_vm_table(&engine.world, &all).render());
    println!("{}", tables::spot_vm_table(&engine.world, &all).render());
    println!("{}", tables::execution_table(&engine.world, &all).render());
    println!("{}", report.render());

    // The canonical lifecycle asserted (so the example doubles as a check):
    let spot_vm = &engine.world.vms[spot];
    let od_vm = &engine.world.vms[od];
    assert_eq!(spot_vm.interruptions, 1, "spot must be interrupted once");
    assert_eq!(spot_vm.history.intervals().len(), 2, "spot must resume");
    assert!(od_vm.history.first_start().unwrap() >= 10.0);
    println!("\nquickstart OK: spot hibernated at t=10 and resumed after the on-demand VM");
}
