"""L2 jax model: the compute graphs the rust coordinator executes via PJRT.

Two entry points, both built on the L1 pallas kernels:

- ``hlem_scores``: the HLEM-VMP host-evaluation pipeline (Eqs. 3-11) over a
  fixed-size padded host batch.  The rust allocation hot path calls the
  compiled artifact once per placement decision (or per scheduling interval,
  scores are VM-independent - see DESIGN.md S4).
- ``cloudlet_step``: batched cloudlet progress update over a fixed-size
  padded cloudlet batch, called once per scheduling-interval tick.

Production artifact shapes (padded by rust, masked in-graph):

- ``MAX_HOSTS = 128``, ``DIMS = 4`` (CPU, RAM, BW, storage) - one VMEM tile.
- ``MAX_CLOUDLETS = 4096`` - four 1024-lane pallas blocks.

This module is imported only at build time by ``aot.py`` and by pytest;
python is never on the simulation request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import cloudlet_step_pallas, hlem_scores_pallas

# Artifact shapes - the contract with rust/src/runtime (see DESIGN.md S5).
MAX_HOSTS = 128
DIMS = 4
MAX_CLOUDLETS = 4096


def hlem_scores(caps, free, spot_used, mask, alpha):
    """HLEM-VMP host scores; thin L2 wrapper over the fused L1 kernel.

    Args:
      caps:      f32[MAX_HOSTS, DIMS] total capacities (padded rows zero).
      free:      f32[MAX_HOSTS, DIMS] available capacities C_i^d(t).
      spot_used: f32[MAX_HOSTS, DIMS] capacity held by spot instances.
      mask:      f32[MAX_HOSTS] 1.0 = candidate host, 0.0 = padded/filtered.
      alpha:     f32[] signed spot-load factor (0.0 -> AHS == HS).

    Returns:
      (hs f32[MAX_HOSTS], ahs f32[MAX_HOSTS]); masked hosts score -1e30.
    """
    return hlem_scores_pallas(caps, free, spot_used, mask, alpha)


def cloudlet_step(remaining, mips, dt):
    """Batched cloudlet progress update; see ``kernels.progress``.

    Args:
      remaining: f32[MAX_CLOUDLETS] outstanding MI (0 = finished/padded).
      mips:      f32[MAX_CLOUDLETS] allocated MIPS per slot.
      dt:        f32[] elapsed simulated seconds.

    Returns:
      (remaining' f32[MAX_CLOUDLETS], finished f32[MAX_CLOUDLETS]).
    """
    return cloudlet_step_pallas(remaining, mips, dt)


def hlem_example_args():
    """ShapeDtypeStructs for lowering ``hlem_scores`` at the artifact shape."""
    mat = jax.ShapeDtypeStruct((MAX_HOSTS, DIMS), jnp.float32)
    vec = jax.ShapeDtypeStruct((MAX_HOSTS,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return (mat, mat, mat, vec, scalar)


def cloudlet_example_args():
    """ShapeDtypeStructs for lowering ``cloudlet_step`` at the artifact shape."""
    vec = jax.ShapeDtypeStruct((MAX_CLOUDLETS,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return (vec, vec, scalar)
