"""AOT compile path: lower the L2 jax entry points to HLO *text* artifacts.

The interchange format is HLO text, NOT ``.serialize()``-d HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` 0.1.6 crate (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``).
The text parser on the rust side reassigns ids and round-trips cleanly - see
/opt/xla-example/load_hlo/ and DESIGN.md S5.

Usage (normally via ``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts

Writes:
    artifacts/hlem_score.hlo.txt
    artifacts/cloudlet_step.hlo.txt
    artifacts/MANIFEST.json        (shapes + input-file hash; used by make
                                    and by the rust runtime as a sanity check)

Idempotent: if MANIFEST.json matches the current source hash the artifacts
are left untouched (``make artifacts`` becomes a no-op).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

_SRC_FILES = [
    "compile/model.py",
    "compile/kernels/__init__.py",
    "compile/kernels/ref.py",
    "compile/kernels/hlem.py",
    "compile/kernels/progress.py",
    "compile/aot.py",
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def source_hash(base_dir: str) -> str:
    """sha256 over the compile-path sources (the MANIFEST freshness key)."""
    h = hashlib.sha256()
    for rel in _SRC_FILES:
        path = os.path.join(base_dir, rel)
        with open(path, "rb") as f:
            h.update(rel.encode())
            h.update(f.read())
    return h.hexdigest()


def build_manifest(src_hash: str) -> dict:
    return {
        "source_hash": src_hash,
        "jax_version": jax.__version__,
        "entry_points": {
            "hlem_score": {
                "file": "hlem_score.hlo.txt",
                "max_hosts": model.MAX_HOSTS,
                "dims": model.DIMS,
                "inputs": [
                    f"caps f32[{model.MAX_HOSTS},{model.DIMS}]",
                    f"free f32[{model.MAX_HOSTS},{model.DIMS}]",
                    f"spot_used f32[{model.MAX_HOSTS},{model.DIMS}]",
                    f"mask f32[{model.MAX_HOSTS}]",
                    "alpha f32[]",
                ],
                "outputs": [
                    f"hs f32[{model.MAX_HOSTS}]",
                    f"ahs f32[{model.MAX_HOSTS}]",
                ],
            },
            "cloudlet_step": {
                "file": "cloudlet_step.hlo.txt",
                "max_cloudlets": model.MAX_CLOUDLETS,
                "inputs": [
                    f"remaining f32[{model.MAX_CLOUDLETS}]",
                    f"mips f32[{model.MAX_CLOUDLETS}]",
                    "dt f32[]",
                ],
                "outputs": [
                    f"remaining f32[{model.MAX_CLOUDLETS}]",
                    f"finished f32[{model.MAX_CLOUDLETS}]",
                ],
            },
        },
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--force", action="store_true", help="rebuild even if fresh")
    args = ap.parse_args()

    base_dir = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out_dir = os.path.abspath(os.path.join(os.getcwd(), args.out_dir))
    os.makedirs(out_dir, exist_ok=True)

    src_hash = source_hash(base_dir)
    manifest_path = os.path.join(out_dir, "MANIFEST.json")
    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("source_hash") == src_hash and all(
                os.path.exists(os.path.join(out_dir, ep["file"]))
                for ep in old.get("entry_points", {}).values()
            ):
                print(f"artifacts fresh (hash {src_hash[:12]}), nothing to do")
                return 0
        except (json.JSONDecodeError, KeyError):
            pass  # stale/corrupt manifest -> rebuild

    lowered_hlem = jax.jit(model.hlem_scores).lower(*model.hlem_example_args())
    hlem_text = to_hlo_text(lowered_hlem)
    hlem_path = os.path.join(out_dir, "hlem_score.hlo.txt")
    with open(hlem_path, "w") as f:
        f.write(hlem_text)
    print(f"wrote {len(hlem_text):>9} chars  {hlem_path}")

    lowered_step = jax.jit(model.cloudlet_step).lower(*model.cloudlet_example_args())
    step_text = to_hlo_text(lowered_step)
    step_path = os.path.join(out_dir, "cloudlet_step.hlo.txt")
    with open(step_path, "w") as f:
        f.write(step_text)
    print(f"wrote {len(step_text):>9} chars  {step_path}")

    with open(manifest_path, "w") as f:
        json.dump(build_manifest(src_hash), f, indent=2)
    print(f"wrote manifest        {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
