"""L1 pallas kernels for cloudmarket (build-time only; never on request path).

- ``hlem``:     fused HLEM-VMP host-evaluation pipeline (Eqs. 3-11).
- ``progress``: batched cloudlet progress update.
- ``ref``:      pure-jnp oracles defining the semantics of both.
"""

from .hlem import hlem_scores_pallas
from .progress import cloudlet_step_pallas
from .ref import cloudlet_step_ref, entropy_weights_ref, hlem_scores_ref

__all__ = [
    "hlem_scores_pallas",
    "cloudlet_step_pallas",
    "hlem_scores_ref",
    "entropy_weights_ref",
    "cloudlet_step_ref",
]
