"""Pure-jnp correctness oracles for the cloudmarket L1 kernels.

These functions define the *semantics* of the two artifacts the rust
coordinator executes:

- ``hlem_scores_ref``: the HLEM-VMP host-evaluation pipeline, Eqs. (3)-(9) of
  the paper, plus the spot-load adjustment of Eqs. (10)-(11).
- ``cloudlet_step_ref``: the batched cloudlet progress update (the paper's
  measured simulation bottleneck, SVII-D.1).

The pallas kernels in ``hlem.py`` / ``progress.py`` must match these to
float32 tolerance; the pure-rust scorer in ``rust/src/allocation/scorer.rs``
implements the identical math and is cross-checked against the AOT artifact
in rust integration tests.

Masking / degenerate-case contract (shared with rust, asserted in tests):

- ``mask[i] == 0`` marks a padded or filtered-out host.  Masked hosts receive
  score ``NEG`` (-1e30) and do not participate in any reduction.
- min-max normalization (Eq. 3): when ``max == min`` over the valid hosts in
  a dimension, the normalized capacity is defined as 0.5 for every valid
  host (all hosts equivalent in that dimension).
- proportional share (Eq. 4): when the valid-host sum of a dimension is 0,
  the share is ``1/n`` (uniform).
- entropy constant (Eq. 6): ``k = 1/ln(n)`` with ``n`` = number of valid
  hosts; for ``n <= 1`` we define ``k = 0`` so that ``e_d = 0`` and the
  weights collapse to uniform via the Eq. (7)-(8) path (all g_d equal).
- Eq. (8) guard: if ``sum_d g_d == 0`` the weights are uniform ``1/D``.
- spot load (Eq. 10): dimensions with zero total capacity contribute 0.
"""

from __future__ import annotations

import jax.numpy as jnp

# Score assigned to masked (padded / filtered-out) hosts.  Large-negative
# instead of -inf so downstream arithmetic can never produce NaNs.
NEG = -1.0e30

# Epsilon guarding the min-max denominator and the weight-sum denominator.
EPS = 1.0e-12


def entropy_weights_ref(free: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Entropy-derived resource weights ``w_d`` (Eqs. 4-8).

    Args:
      free: ``f32[H, D]`` available capacity per host and resource dimension.
      mask: ``f32[H]`` 1.0 for valid candidate hosts, 0.0 otherwise.

    Returns:
      ``f32[D]`` weights, summing to 1.
    """
    free = jnp.asarray(free, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    h, d = free.shape
    m = mask[:, None]  # [H, 1]
    n = jnp.sum(mask)  # valid host count

    # Eq. (4): proportional share of each dimension held by each host.
    col_sum = jnp.sum(free * m, axis=0)  # [D]
    uniform = jnp.where(n > 0, 1.0 / jnp.maximum(n, 1.0), 0.0)
    p = jnp.where(col_sum[None, :] > EPS, free / jnp.maximum(col_sum[None, :], EPS), uniform)
    p = p * m  # masked hosts contribute nothing

    # Eq. (5)-(6): entropy with k = 1/ln(n); define k = 0 for n <= 1.
    plogp = jnp.where(p > 0.0, p * jnp.log(jnp.maximum(p, EPS)), 0.0)
    k = jnp.where(n > 1.0, 1.0 / jnp.log(jnp.maximum(n, 2.0)), 0.0)
    e = -k * jnp.sum(plogp, axis=0)  # [D]

    # Eq. (7)-(8): variation factors -> normalized weights.
    g = 1.0 - e
    gsum = jnp.sum(g)
    w = jnp.where(gsum > EPS, g / jnp.maximum(gsum, EPS), jnp.full((d,), 1.0 / d, jnp.float32))
    return w.astype(jnp.float32)


def hlem_scores_ref(
    caps: jnp.ndarray,
    free: jnp.ndarray,
    spot_used: jnp.ndarray,
    mask: jnp.ndarray,
    alpha: jnp.ndarray,
):
    """HLEM-VMP host scores ``HS_i`` (Eq. 9) and adjusted ``AHS_i`` (Eq. 11).

    Args:
      caps:      ``f32[H, D]`` total capacity per host / dimension.
      free:      ``f32[H, D]`` currently available capacity ``C_i^d(t)``.
      spot_used: ``f32[H, D]`` capacity consumed by spot instances.
      mask:      ``f32[H]`` candidate mask (1 valid, 0 padded/filtered).
      alpha:     ``f32[]`` signed spot-load factor (negative = penalty).

    Returns:
      ``(hs f32[H], ahs f32[H])`` with masked hosts at ``NEG``.
    """
    caps = jnp.asarray(caps, jnp.float32)
    free = jnp.asarray(free, jnp.float32)
    spot_used = jnp.asarray(spot_used, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    alpha = jnp.asarray(alpha, jnp.float32)

    m = mask[:, None]

    # Eq. (3): min-max normalization over *valid* hosts per dimension.
    big = jnp.float32(3.0e38)
    mn = jnp.min(jnp.where(m > 0.0, free, big), axis=0)  # [D]
    mx = jnp.max(jnp.where(m > 0.0, free, -big), axis=0)  # [D]
    rng = mx - mn
    cnorm = jnp.where(rng[None, :] > EPS, (free - mn[None, :]) / jnp.maximum(rng[None, :], EPS), 0.5)

    w = entropy_weights_ref(free, mask)  # [D]

    # Eq. (9): weighted sum of normalized capacities.
    hs = jnp.sum(w[None, :] * cnorm, axis=1)  # [H]

    # Eq. (10): spot load = weighted fraction of capacity held by spot VMs.
    frac = jnp.where(caps > EPS, spot_used / jnp.maximum(caps, EPS), 0.0)
    sl = jnp.sum(w[None, :] * frac, axis=1)  # [H]

    # Eq. (11): adjusted host score.
    ahs = hs * (1.0 + alpha * sl)

    hs = jnp.where(mask > 0.0, hs, NEG)
    ahs = jnp.where(mask > 0.0, ahs, NEG)
    return hs.astype(jnp.float32), ahs.astype(jnp.float32)


def cloudlet_step_ref(remaining: jnp.ndarray, mips: jnp.ndarray, dt: jnp.ndarray):
    """Batched cloudlet progress update.

    ``remaining`` holds outstanding instructions (MI) per cloudlet slot
    (0 for finished or padded slots), ``mips`` the MIPS currently allocated
    to that cloudlet, ``dt`` the elapsed simulated seconds.

    Returns ``(remaining', finished)`` where ``finished`` is 1.0 exactly for
    slots that crossed to completion in this step.
    """
    remaining = jnp.asarray(remaining, jnp.float32)
    mips = jnp.asarray(mips, jnp.float32)
    dt = jnp.asarray(dt, jnp.float32)
    nxt = jnp.maximum(remaining - mips * dt, 0.0)
    finished = jnp.where((remaining > 0.0) & (nxt <= 0.0), 1.0, 0.0)
    return nxt.astype(jnp.float32), finished.astype(jnp.float32)
