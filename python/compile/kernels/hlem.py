"""L1 pallas kernel: fused HLEM-VMP host-evaluation pipeline (Eqs. 3-11).

One fused kernel computes, for a padded batch of ``H`` hosts and ``D``
resource dimensions:

  min-max normalize -> proportional shares -> per-dimension entropy ->
  variation factors -> weights -> host score HS -> spot load SL ->
  adjusted score AHS

TPU design notes (see DESIGN.md SHardware-Adaptation):

- The paper has no GPU kernel to port; the hot-spot is a small dense
  pipeline executed on *every* placement decision.  We lay the data out as
  ``[D, H]`` (resource dimensions in sublanes, hosts in lanes) so that with
  the production shape ``H = 128`` the host axis exactly fills a TPU lane
  register and every reduction over hosts is a lane reduction.  The whole
  working set (5 x D x H x 4 B = 10 KB at D=4, H=128) fits a single
  VMEM-resident block, so the grid is trivial: one program, zero HBM
  round-trips between pipeline stages (the Java original walks host lists
  object-by-object per stage).
- ``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
  Mosaic custom-calls.  The interpret path lowers to plain HLO, which is
  what ``aot.py`` ships to the rust runtime.

The public entry point ``hlem_scores_pallas`` keeps the oracle's ``[H, D]``
interface and transposes at the boundary (XLA folds the transposes into the
surrounding fusion).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS, NEG

# f32 sentinel bounds for masked lane reductions (finite: avoids inf-inf NaNs).
_BIG = 3.0e38


def _hlem_kernel(caps_ref, free_ref, spot_ref, mask_ref, alpha_ref, hs_ref, ahs_ref):
    """Fused scoring kernel over one ``[D, H]`` block.

    Refs:
      caps_ref:  f32[D, H] total capacities (transposed).
      free_ref:  f32[D, H] available capacities (transposed).
      spot_ref:  f32[D, H] spot-consumed capacities (transposed).
      mask_ref:  f32[1, H] candidate mask.
      alpha_ref: f32[1, 1] signed spot-load factor.
      hs_ref:    f32[1, H] out - Eq. (9) host scores.
      ahs_ref:   f32[1, H] out - Eq. (11) adjusted scores.
    """
    caps = caps_ref[...]
    free = free_ref[...]
    spot = spot_ref[...]
    m = mask_ref[...]  # [1, H]
    alpha = alpha_ref[0, 0]

    d = free.shape[0]
    n = jnp.sum(m)  # valid host count (scalar)

    # --- Eq. (3): min-max normalization over valid hosts (lane reduction) ---
    mn = jnp.min(jnp.where(m > 0.0, free, _BIG), axis=1, keepdims=True)  # [D, 1]
    mx = jnp.max(jnp.where(m > 0.0, free, -_BIG), axis=1, keepdims=True)  # [D, 1]
    rng = mx - mn
    cnorm = jnp.where(rng > EPS, (free - mn) / jnp.maximum(rng, EPS), 0.5)  # [D, H]

    # --- Eq. (4): proportional shares ---
    col_sum = jnp.sum(free * m, axis=1, keepdims=True)  # [D, 1]
    uniform = jnp.where(n > 0.0, 1.0 / jnp.maximum(n, 1.0), 0.0)
    p = jnp.where(col_sum > EPS, free / jnp.maximum(col_sum, EPS), uniform) * m  # [D, H]

    # --- Eq. (5)-(6): per-dimension entropy, k = 1/ln(n) (k = 0 for n <= 1) ---
    plogp = jnp.where(p > 0.0, p * jnp.log(jnp.maximum(p, EPS)), 0.0)
    k = jnp.where(n > 1.0, 1.0 / jnp.log(jnp.maximum(n, 2.0)), 0.0)
    e = -k * jnp.sum(plogp, axis=1, keepdims=True)  # [D, 1]

    # --- Eq. (7)-(8): variation factors -> weights ---
    g = 1.0 - e  # [D, 1]
    gsum = jnp.sum(g)
    w = jnp.where(gsum > EPS, g / jnp.maximum(gsum, EPS), jnp.full((d, 1), 1.0 / d, jnp.float32))

    # --- Eq. (9): host score (sublane reduction, D is tiny) ---
    hs = jnp.sum(w * cnorm, axis=0, keepdims=True)  # [1, H]

    # --- Eq. (10)-(11): spot load and adjusted score ---
    frac = jnp.where(caps > EPS, spot / jnp.maximum(caps, EPS), 0.0)
    sl = jnp.sum(w * frac, axis=0, keepdims=True)  # [1, H]
    ahs = hs * (1.0 + alpha * sl)

    hs_ref[...] = jnp.where(m > 0.0, hs, NEG)
    ahs_ref[...] = jnp.where(m > 0.0, ahs, NEG)


@functools.partial(jax.jit, static_argnames=())
def hlem_scores_pallas(caps, free, spot_used, mask, alpha):
    """Pallas-backed HLEM-VMP scores with the oracle's ``[H, D]`` interface.

    Args / returns: identical to ``ref.hlem_scores_ref``.
    """
    caps = jnp.asarray(caps, jnp.float32)
    free = jnp.asarray(free, jnp.float32)
    spot_used = jnp.asarray(spot_used, jnp.float32)
    mask = jnp.asarray(mask, jnp.float32)
    alpha = jnp.asarray(alpha, jnp.float32)

    h, _d = caps.shape
    hs, ahs = pl.pallas_call(
        _hlem_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1, h), jnp.float32),
            jax.ShapeDtypeStruct((1, h), jnp.float32),
        ),
        interpret=True,
    )(caps.T, free.T, spot_used.T, mask.reshape(1, h), alpha.reshape(1, 1))
    return hs.reshape(h), ahs.reshape(h)
