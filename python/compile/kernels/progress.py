"""L1 pallas kernel: batched cloudlet progress update.

The paper identifies per-cloudlet execution updates as the dominant cost of
its trace-scale simulations (SVII-D.1: "performance was constrained by
cloudlet execution updates ... suggesting parallelization as a future
optimization").  This kernel *is* that parallelization: one scheduling-
interval tick advances every running cloudlet at once.

TPU design notes:

- Cloudlets are tiled in ``BLOCK = 1024``-lane blocks along the batch axis
  via ``BlockSpec`` - the HBM<->VMEM schedule that replaces the Java
  per-object update loop.  Each block is 3 x 1024 x 4 B = 12 KB of VMEM,
  leaving headroom to double-buffer blocks while the VPU processes the
  previous one (pallas pipelines grid steps automatically).
- Pure elementwise VPU work; the MXU is idle by design (no matmul in this
  computation).  The roofline comparison is therefore against the memory-
  bound jnp reference, see EXPERIMENTS.md SPerf.
- ``interpret=True`` as required for the CPU PJRT execution path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _progress_kernel(rem_ref, mips_ref, dt_ref, out_rem_ref, out_fin_ref):
    """Advance one block of cloudlets by ``dt`` simulated seconds.

    Refs:
      rem_ref:     f32[1, BLOCK] remaining instructions (MI).
      mips_ref:    f32[1, BLOCK] allocated MIPS.
      dt_ref:      f32[1, 1] elapsed simulated seconds.
      out_rem_ref: f32[1, BLOCK] out - updated remaining MI.
      out_fin_ref: f32[1, BLOCK] out - 1.0 where the cloudlet just finished.
    """
    rem = rem_ref[...]
    mips = mips_ref[...]
    dt = dt_ref[0, 0]
    nxt = jnp.maximum(rem - mips * dt, 0.0)
    out_rem_ref[...] = nxt
    out_fin_ref[...] = jnp.where((rem > 0.0) & (nxt <= 0.0), 1.0, 0.0)


@functools.partial(jax.jit, static_argnames=())
def cloudlet_step_pallas(remaining, mips, dt):
    """Pallas-backed batched progress update; interface of ``ref.cloudlet_step_ref``.

    ``remaining``/``mips`` must share a length that is a multiple of
    ``BLOCK`` for the production artifact; arbitrary lengths are padded here
    so property tests can sweep shapes.
    """
    remaining = jnp.asarray(remaining, jnp.float32)
    mips = jnp.asarray(mips, jnp.float32)
    dt = jnp.asarray(dt, jnp.float32)

    n = remaining.shape[0]
    padded = ((n + BLOCK - 1) // BLOCK) * BLOCK
    pad = padded - n
    rem_p = jnp.pad(remaining, (0, pad)).reshape(1, padded)
    mips_p = jnp.pad(mips, (0, pad)).reshape(1, padded)

    grid = padded // BLOCK
    out_rem, out_fin = pl.pallas_call(
        _progress_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
            pl.BlockSpec((1, BLOCK), lambda i: (0, i)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((1, padded), jnp.float32),
            jax.ShapeDtypeStruct((1, padded), jnp.float32),
        ),
        interpret=True,
    )(rem_p, mips_p, dt.reshape(1, 1))
    return out_rem.reshape(padded)[:n], out_fin.reshape(padded)[:n]
