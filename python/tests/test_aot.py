"""AOT lowering sanity: the L2 entry points lower to loadable HLO text.

These tests exercise the exact code path ``make artifacts`` runs, without
writing into ``artifacts/`` (tmp dirs).  They guard the interchange contract
with the rust runtime (DESIGN.md S5): parameter count/order, tuple return,
and manifest freshness behaviour.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from compile import aot, model


def test_hlem_lowering_produces_hlo_text():
    lowered = jax.jit(model.hlem_scores).lower(*model.hlem_example_args())
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # Entry layout declares 5 parameters in order; f32 at artifact shapes.
    layout = text.split("entry_computation_layout={(", 1)[1].split(")->")[0]
    assert layout.count("f32[") == 5
    assert f"f32[{model.MAX_HOSTS},{model.DIMS}]" in layout
    assert f"f32[{model.MAX_HOSTS}]" in layout


def test_cloudlet_lowering_produces_hlo_text():
    lowered = jax.jit(model.cloudlet_step).lower(*model.cloudlet_example_args())
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    layout = text.split("entry_computation_layout={(", 1)[1].split(")->")[0]
    assert layout.count("f32[") == 3
    assert f"f32[{model.MAX_CLOUDLETS}]" in layout


def test_lowered_hlem_executes_and_matches_eager():
    """The lowered module computes the same numbers the eager path does."""
    rng = np.random.default_rng(0)
    caps = rng.uniform(1, 100, size=(model.MAX_HOSTS, model.DIMS)).astype(np.float32)
    free = (caps * rng.uniform(0, 1, size=caps.shape)).astype(np.float32)
    spot = (free * 0.3).astype(np.float32)
    mask = np.zeros(model.MAX_HOSTS, np.float32)
    mask[:100] = 1.0
    alpha = np.float32(-0.5)

    compiled = jax.jit(model.hlem_scores).lower(caps, free, spot, mask, alpha).compile()
    hs_c, ahs_c = compiled(caps, free, spot, mask, alpha)
    hs_e, ahs_e = model.hlem_scores(caps, free, spot, mask, alpha)
    np.testing.assert_allclose(np.asarray(hs_c), np.asarray(hs_e), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ahs_c), np.asarray(ahs_e), rtol=1e-6)


def test_manifest_shapes_match_model():
    m = aot.build_manifest("dummy")
    eps = m["entry_points"]
    assert eps["hlem_score"]["max_hosts"] == model.MAX_HOSTS
    assert eps["hlem_score"]["dims"] == model.DIMS
    assert eps["cloudlet_step"]["max_cloudlets"] == model.MAX_CLOUDLETS


@pytest.mark.slow
def test_aot_main_is_idempotent(tmp_path):
    """Second invocation with unchanged sources is a no-op (make contract)."""
    env = dict(os.environ)
    pydir = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = tmp_path / "artifacts"

    def run():
        return subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
            cwd=pydir, env=env, capture_output=True, text=True, timeout=600,
        )

    r1 = run()
    assert r1.returncode == 0, r1.stderr
    manifest1 = json.loads((out / "MANIFEST.json").read_text())
    mtime1 = (out / "hlem_score.hlo.txt").stat().st_mtime_ns

    r2 = run()
    assert r2.returncode == 0, r2.stderr
    assert "fresh" in r2.stdout
    assert (out / "hlem_score.hlo.txt").stat().st_mtime_ns == mtime1
    assert json.loads((out / "MANIFEST.json").read_text()) == manifest1
