"""Kernel-vs-oracle correctness for the fused HLEM-VMP pallas kernel.

This is the CORE L1 correctness signal: ``hlem_scores_pallas`` (what the AOT
artifact is built from) must match ``hlem_scores_ref`` (pure jnp, a direct
transcription of Eqs. 3-11) across shapes, masks and degenerate inputs.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hlem_scores_pallas
from compile.kernels.ref import NEG, entropy_weights_ref, hlem_scores_ref

RTOL = 1e-5
ATOL = 1e-5


def _rand_inputs(rng, h, d, mask_p=0.8, equal_dim=None, zero_dim=None):
    caps = rng.uniform(1.0, 100.0, size=(h, d)).astype(np.float32)
    free = (caps * rng.uniform(0.0, 1.0, size=(h, d))).astype(np.float32)
    spot = (free * rng.uniform(0.0, 1.0, size=(h, d))).astype(np.float32)
    mask = (rng.uniform(size=h) < mask_p).astype(np.float32)
    if mask.sum() == 0:
        mask[0] = 1.0
    if equal_dim is not None:
        free[:, equal_dim] = 7.5  # max == min degenerate case
    if zero_dim is not None:
        free[:, zero_dim] = 0.0  # zero column-sum degenerate case
    alpha = np.float32(rng.uniform(-1.0, 1.0))
    return caps, free, spot, mask, alpha


def _check(caps, free, spot, mask, alpha):
    hs_k, ahs_k = hlem_scores_pallas(caps, free, spot, mask, alpha)
    hs_r, ahs_r = hlem_scores_ref(caps, free, spot, mask, alpha)
    np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_r), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(ahs_k), np.asarray(ahs_r), rtol=RTOL, atol=ATOL)
    return np.asarray(hs_k), np.asarray(ahs_k)


@pytest.mark.parametrize("h", [2, 3, 8, 17, 64, 128])
@pytest.mark.parametrize("d", [1, 2, 4, 6])
def test_matches_ref_across_shapes(h, d):
    rng = np.random.default_rng(h * 1000 + d)
    _check(*_rand_inputs(rng, h, d))


@pytest.mark.parametrize("seed", range(8))
def test_matches_ref_production_shape(seed):
    rng = np.random.default_rng(seed)
    _check(*_rand_inputs(rng, 128, 4))


def test_degenerate_equal_dimension():
    """max == min in one dimension -> normalized capacity 0.5 (contract)."""
    rng = np.random.default_rng(42)
    _check(*_rand_inputs(rng, 16, 4, equal_dim=2))


def test_degenerate_zero_dimension():
    """column sum 0 -> proportional share 1/n (contract)."""
    rng = np.random.default_rng(43)
    _check(*_rand_inputs(rng, 16, 4, zero_dim=1))


def test_single_valid_host():
    """n == 1 -> entropy path collapses to uniform weights without NaNs."""
    rng = np.random.default_rng(44)
    caps, free, spot, mask, alpha = _rand_inputs(rng, 8, 4)
    mask[:] = 0.0
    mask[3] = 1.0
    hs, ahs = _check(caps, free, spot, mask, alpha)
    assert np.isfinite(hs[3]) and np.isfinite(ahs[3])
    assert (hs[np.arange(8) != 3] == NEG).all()


def test_all_hosts_identical():
    """Identical hosts -> identical (and finite) scores."""
    caps = np.full((8, 4), 50.0, np.float32)
    free = np.full((8, 4), 20.0, np.float32)
    spot = np.full((8, 4), 5.0, np.float32)
    mask = np.ones(8, np.float32)
    hs, ahs = _check(caps, free, spot, mask, np.float32(-0.5))
    assert np.allclose(hs, hs[0]) and np.allclose(ahs, ahs[0])
    assert np.isfinite(hs).all()


def test_alpha_zero_means_no_adjustment():
    rng = np.random.default_rng(45)
    caps, free, spot, mask, _ = _rand_inputs(rng, 32, 4)
    hs, ahs = _check(caps, free, spot, mask, np.float32(0.0))
    np.testing.assert_allclose(hs, ahs, rtol=RTOL, atol=ATOL)


def test_negative_alpha_penalizes_spot_heavy_hosts():
    """With alpha < 0 a host identical except for spot load scores lower."""
    caps = np.full((2, 4), 100.0, np.float32)
    free = np.full((2, 4), 40.0, np.float32)
    spot = np.zeros((2, 4), np.float32)
    spot[1, :] = 50.0  # host 1 carries heavy spot load
    mask = np.ones(2, np.float32)
    _, ahs = _check(caps, free, spot, mask, np.float32(-0.5))
    assert ahs[1] < ahs[0]


def test_masked_hosts_do_not_influence_scores():
    """Garbage in masked rows must not perturb valid hosts' scores."""
    rng = np.random.default_rng(46)
    caps, free, spot, mask, alpha = _rand_inputs(rng, 16, 4, mask_p=1.0)
    mask[10:] = 0.0
    hs_a, ahs_a = hlem_scores_ref(caps, free, spot, mask, alpha)
    caps2, free2, spot2 = caps.copy(), free.copy(), spot.copy()
    caps2[10:], free2[10:], spot2[10:] = 9e9, 9e9, 9e9
    hs_b, ahs_b = hlem_scores_pallas(caps2, free2, spot2, mask, alpha)
    np.testing.assert_allclose(np.asarray(hs_b)[:10], np.asarray(hs_a)[:10], rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(ahs_b)[:10], np.asarray(ahs_a)[:10], rtol=RTOL, atol=ATOL)


def test_entropy_weights_sum_to_one():
    rng = np.random.default_rng(47)
    for _ in range(5):
        caps, free, _, mask, _ = _rand_inputs(rng, 24, 4)
        w = np.asarray(entropy_weights_ref(free, mask))
        assert abs(w.sum() - 1.0) < 1e-5
        assert (w >= -1e-6).all()


@settings(max_examples=40, deadline=None)
@given(
    h=st.integers(min_value=2, max_value=48),
    d=st.integers(min_value=1, max_value=5),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    alpha=st.floats(min_value=-2.0, max_value=2.0, width=32),
)
def test_hypothesis_sweep(h, d, seed, alpha):
    """Hypothesis sweep: kernel == oracle over random shapes/masks/alphas."""
    rng = np.random.default_rng(seed)
    caps, free, spot, mask, _ = _rand_inputs(rng, h, d)
    _check(caps, free, spot, mask, np.float32(alpha))
