"""Property tests on the oracle itself (Eqs. 3-11 invariants).

The oracle is the single source of truth for three implementations (pallas
kernel, lowered artifact, pure-rust scorer), so its own mathematical
invariants deserve direct coverage.
"""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import NEG, entropy_weights_ref, hlem_scores_ref


def _inputs(seed, h=16, d=4):
    rng = np.random.default_rng(seed)
    caps = rng.uniform(1, 100, size=(h, d)).astype(np.float32)
    free = (caps * rng.uniform(0, 1, size=(h, d))).astype(np.float32)
    spot = (free * rng.uniform(0, 1, size=(h, d))).astype(np.float32)
    mask = np.ones(h, np.float32)
    return caps, free, spot, mask


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), h=st.integers(2, 40), d=st.integers(1, 6))
def test_weights_form_a_distribution(seed, h, d):
    _, free, _, mask = _inputs(seed, h, d)
    w = np.asarray(entropy_weights_ref(free, mask))
    assert w.shape == (d,)
    assert abs(w.sum() - 1.0) < 1e-5
    assert (w >= -1e-6).all()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_scores_bounded_for_valid_hosts(seed):
    """HS is a convex combination of values in [0,1] -> HS in [0,1]."""
    caps, free, spot, mask = _inputs(seed)
    hs, _ = hlem_scores_ref(caps, free, spot, mask, np.float32(0.0))
    hs = np.asarray(hs)
    assert ((hs >= -1e-5) & (hs <= 1.0 + 1e-5)).all()


def test_more_free_capacity_scores_higher():
    """A host dominating another in every dimension never scores lower."""
    h, d = 8, 4
    rng = np.random.default_rng(1)
    caps = np.full((h, d), 100.0, np.float32)
    free = rng.uniform(10, 50, size=(h, d)).astype(np.float32)
    free[0] = free[1] + 20.0  # host 0 strictly dominates host 1
    spot = np.zeros((h, d), np.float32)
    mask = np.ones(h, np.float32)
    hs, _ = hlem_scores_ref(caps, free, spot, mask, np.float32(0.0))
    assert np.asarray(hs)[0] >= np.asarray(hs)[1]


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), alpha=st.floats(-2.0, 2.0, width=32))
def test_ahs_sign_consistency(seed, alpha):
    """alpha = 0 -> AHS == HS; masked hosts always NEG."""
    caps, free, spot, mask = _inputs(seed)
    mask[-3:] = 0.0
    hs, ahs = hlem_scores_ref(caps, free, spot, mask, np.float32(alpha))
    hs, ahs = np.asarray(hs), np.asarray(ahs)
    assert (hs[-3:] == NEG).all() and (ahs[-3:] == NEG).all()
    hs0, ahs0 = hlem_scores_ref(caps, free, spot, mask, np.float32(0.0))
    np.testing.assert_allclose(np.asarray(hs0), np.asarray(ahs0))


def test_zero_spot_usage_means_no_adjustment():
    caps, free, _, mask = _inputs(5)
    spot = np.zeros_like(free)
    hs, ahs = hlem_scores_ref(caps, free, spot, mask, np.float32(-0.7))
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ahs), rtol=1e-6)


def test_scale_invariance_of_weights():
    """Scaling one dimension's units (MB vs GB) must not change weights."""
    _, free, _, mask = _inputs(9)
    w1 = np.asarray(entropy_weights_ref(free, mask))
    scaled = free.copy()
    scaled[:, 2] *= 1024.0
    w2 = np.asarray(entropy_weights_ref(scaled, mask))
    np.testing.assert_allclose(w1, w2, rtol=1e-4, atol=1e-5)
