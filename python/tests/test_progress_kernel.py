"""Kernel-vs-oracle correctness for the batched cloudlet-progress kernel."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cloudlet_step_pallas
from compile.kernels.progress import BLOCK
from compile.kernels.ref import cloudlet_step_ref


def _check(remaining, mips, dt):
    rem_k, fin_k = cloudlet_step_pallas(remaining, mips, dt)
    rem_r, fin_r = cloudlet_step_ref(remaining, mips, dt)
    rem_k, fin_k = np.asarray(rem_k), np.asarray(fin_k)
    rem_r, fin_r = np.asarray(rem_r), np.asarray(fin_r)
    # FMA-vs-separate rounding differs between the interpret-mode kernel and
    # the jnp reference; for `rem - mips*dt` the error scales with the
    # *operand* magnitude (cancellation), not the result, so atol is derived
    # from the largest operand (float32 eps ~ 1.2e-7).
    scale = float(max(np.max(np.abs(remaining), initial=1.0),
                      np.max(np.abs(mips), initial=1.0) * float(dt), 1.0))
    atol = 1e-6 * scale + 1e-6
    np.testing.assert_allclose(rem_k, rem_r, rtol=1e-5, atol=atol)
    # `finished` must agree except on slots that land within float noise of
    # the completion boundary, where FMA rounding may legitimately flip it.
    decided = rem_r > atol
    np.testing.assert_array_equal(fin_k[decided], fin_r[decided])
    return rem_k, fin_k


@pytest.mark.parametrize("n", [1, 7, 1024, 1025, 4096, 5000])
def test_matches_ref_across_lengths(n):
    rng = np.random.default_rng(n)
    remaining = rng.uniform(0.0, 1e6, size=n).astype(np.float32)
    remaining[rng.uniform(size=n) < 0.2] = 0.0  # finished/padded slots
    mips = rng.uniform(0.0, 5000.0, size=n).astype(np.float32)
    _check(remaining, mips, np.float32(rng.uniform(0.1, 10.0)))


def test_exact_completion_edge():
    """A cloudlet whose remaining MI exactly equals mips*dt finishes."""
    remaining = np.array([1000.0, 1000.0, 0.0], np.float32)
    mips = np.array([100.0, 50.0, 100.0], np.float32)
    rem, fin = _check(remaining, mips, np.float32(10.0))
    assert rem[0] == 0.0 and fin[0] == 1.0  # exact hit
    assert rem[1] == 500.0 and fin[1] == 0.0  # still running
    assert rem[2] == 0.0 and fin[2] == 0.0  # already finished: no re-fire


def test_zero_dt_is_identity():
    rng = np.random.default_rng(7)
    remaining = rng.uniform(0.0, 1e5, size=256).astype(np.float32)
    mips = rng.uniform(0.0, 1e3, size=256).astype(np.float32)
    rem, fin = _check(remaining, mips, np.float32(0.0))
    np.testing.assert_array_equal(rem, remaining)
    assert fin.sum() == 0.0


def test_zero_mips_makes_no_progress():
    """Hibernate semantics: deallocated VMs (0 MIPS) freeze their cloudlets."""
    remaining = np.full(64, 5e4, np.float32)
    mips = np.zeros(64, np.float32)
    rem, fin = _check(remaining, mips, np.float32(100.0))
    np.testing.assert_array_equal(rem, remaining)
    assert fin.sum() == 0.0


def test_block_boundary_independence():
    """Slots at pallas block boundaries behave like interior slots."""
    n = 3 * BLOCK
    remaining = np.full(n, 1e4, np.float32)
    mips = np.full(n, 100.0, np.float32)
    rem, fin = _check(remaining, mips, np.float32(1.0))
    assert np.unique(rem).size == 1 and np.unique(fin).size == 1


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dt=st.floats(min_value=0.0, max_value=1e4, width=32),
)
def test_hypothesis_sweep(n, seed, dt):
    rng = np.random.default_rng(seed)
    remaining = rng.uniform(0.0, 1e6, size=n).astype(np.float32)
    mips = rng.uniform(0.0, 1e4, size=n).astype(np.float32)
    _check(remaining, mips, np.float32(dt))
